//! The elastic worker pool: one scheduler that owns every PID's
//! lifecycle — spawn, run, drain, retire — and the control channel both
//! engines drive it through.
//!
//! The paper's §4.3 speed adaptation has two halves. The *fixed-pool*
//! half (PR 2) moves ownership between a constant K workers; this module
//! adds the *elastic* half: the PID count itself tracks the workload
//! (arXiv 1203.1715 evaluates exactly this dynamic-partition policy, and
//! the flexible-communication results of arXiv 2210.04626 justify
//! convergence with endpoints that appear and disappear mid-iteration).
//!
//! ## Lifecycle (DESIGN.md §6)
//!
//! ```text
//!            add_endpoint        handoff folded
//! (vacant) ──────────────▶ Spawning ────────────▶ Live
//!                                                  │ drain install
//!                                                  ▼
//!            remove_endpoint + join            Draining
//! (vacant) ◀──────────────────────── Retired ◀─────┘
//!                                        acked ∧ inflight == 0
//! ```
//!
//! **Spawn** (a persistent straggler, PID headroom available): reserve a
//! slot → register its bus endpoint → widen the [`OwnershipTable`] →
//! start the worker on an **empty** `LocalSystem` (it enters the current
//! epoch with a zero-length fluid slice) → install the cut-aware half of
//! the straggler's Ω. The straggler itself ships the `(H, B, F)` slice
//! over the PR 2 [`super::worker::Handoff`] machinery; the new worker's
//! adopt-from-empty is just the ordinary handoff fold.
//!
//! **Retire** (a worker idle past the policy window): install a
//! transfer of its whole Ω to an absorber (the part goes empty, the slot
//! stays) → wait until the drain acked and no handoff slice is in flight
//! → deregister the endpoint **first**, then shut the thread down. The
//! order matters: after `remove_endpoint` returns, stale senders fail
//! fast and re-route, while everything already queued is drained by the
//! worker's forwarding exit path ([`WorkerCore::finish`]) — so a retire
//! mid-convergence conserves every unit of fluid.
//!
//! Both transitions run **asynchronously** against the diffusion: the
//! pool installs an ownership version and lets the workers migrate state
//! themselves; `poll` completes the lifecycle transitions on later
//! ticks. All pool operations happen on the engine's monitor thread, so
//! they are serial with epoch rebases (which freeze the table anyway).

use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::adaptive::choose_shed_half;
use super::monitor::MonitorState;
use super::query::QUERY_METRICS;
use super::worker::{WorkerCore, WorkerMsg, WORKER_METRICS};
use super::DistributedConfig;
use crate::error::{DiterError, Result};
use crate::metrics::MetricSet;
use crate::partition::{OwnershipTable, PidState};
use crate::solver::FixedPointProblem;
use crate::transport::{fabric, BusConfig, BusMonitor, Transport, TransportHub};

/// Pool gauges registered on top of the worker/bus metrics.
pub const POOL_METRICS: &[&str] = &[
    "pool_spawned",   // workers spawned at runtime
    "pool_retired",   // workers retired at runtime
    "pool_live",      // current live worker count (gauge)
    "pool_peak_live", // high-water mark of live workers
];

/// Coordinator → worker control messages. Checkpoint/Snapshot replies
/// carry `(pid, held coords, H slice)` — with live repartitioning the
/// held range is dynamic, so the coordinates always travel with the data.
pub(crate) enum Ctrl {
    /// Pause, reply with the held range + H slice, wait for `Resume`.
    Checkpoint {
        reply: Sender<(usize, Vec<usize>, Vec<f64>)>,
    },
    /// New epoch: swap the matrix, reset the fluid slice, keep H.
    /// `dirty` lists the matrix columns that changed since the previous
    /// epoch (ascending) when the incremental build knows them — workers
    /// patch their `LocalSystem` instead of rebuilding it.
    Resume {
        epoch: u64,
        problem: Arc<FixedPointProblem>,
        f_slice: Vec<f64>,
        dirty: Option<Arc<Vec<usize>>>,
    },
    /// Non-pausing read of the held range + H (worker keeps running).
    Snapshot {
        reply: Sender<(usize, Vec<usize>, Vec<f64>)>,
    },
    /// V1-style local epoch transition ([`super::RebaseMode::Local`]):
    /// the worker freezes its owned dirty columns, exchanges halo H
    /// values with its peers over the bus, rebases its own fluid slice in
    /// place, and sends its pid on `reply` once it has entered `epoch` —
    /// all without pausing the diffusion of non-dirty fluid. No
    /// checkpoint, no scatter.
    RebaseLocal {
        epoch: u64,
        problem: Arc<FixedPointProblem>,
        /// the mutation delta: matrix columns that changed, ascending
        dirty: Arc<Vec<usize>>,
        reply: Sender<usize>,
    },
    /// Terminate; the final (Ω, H) comes back through the join handle.
    Shutdown,
}

/// Elastic policy knobs (`--max-workers`, `--spawn-threshold`,
/// `--retire-idle-ms` on the CLI).
#[derive(Clone, Debug)]
pub struct ElasticConfig {
    /// hard cap on concurrently-live workers (bus/table/monitor capacity
    /// is pre-sized to this)
    pub max_workers: usize,
    /// spawn a worker for a straggler whose per-coordinate rate is below
    /// this fraction of the median (the §4.3 split criterion)
    pub spawn_threshold: f64,
    /// retire a worker continuously idle (no updates, no backlog) for
    /// this long
    pub retire_idle: Duration,
    /// decision window: rates are measured and at most one lifecycle
    /// operation is started per interval
    pub interval: Duration,
    /// never split a part below 2× this many coordinates
    pub min_part: usize,
    /// never retire below this many live workers
    pub min_workers: usize,
    /// hard cap on lifecycle operations per run (runaway guard)
    pub max_ops: u64,
}

impl Default for ElasticConfig {
    fn default() -> Self {
        Self {
            max_workers: 8,
            spawn_threshold: 0.5,
            retire_idle: Duration::from_millis(250),
            interval: Duration::from_millis(40),
            min_part: 2,
            min_workers: 1,
            max_ops: 64,
        }
    }
}

/// Lifecycle counters exposed to engines, the CLI stats block and the
/// elastic bench.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PoolStats {
    /// workers spawned at runtime (beyond the initial K)
    pub spawned: u64,
    /// workers retired at runtime
    pub retired: u64,
    /// ownership sheds installed by the pool (straggler relief when the
    /// pool is at max_workers)
    pub sheds: u64,
    /// high-water mark of concurrently-live workers
    pub peak_live: usize,
    /// live workers right now
    pub live: usize,
}

/// One PID slot's worker-side handles.
struct WorkerHandle {
    ctrl: Sender<Ctrl>,
    handle: JoinHandle<(Vec<usize>, Vec<f64>)>,
}

/// Elastic driver state (None on a fixed pool).
struct ElasticState {
    cfg: ElasticConfig,
    last_counts: Vec<u64>,
    last_decision: Instant,
    /// per-slot instant the worker was first observed idle (None = busy)
    idle_since: Vec<Option<Instant>>,
    /// below this much total fluid no spawn/shed fires (nearly drained —
    /// migrating buys nothing); retire stays allowed, that IS the win
    min_total: f64,
    ops: u64,
}

/// The worker-pool scheduler: owns the bus hub, the ownership table, the
/// monitor slots, and every worker thread. Both engines
/// ([`super::v2::solve_v2`] and [`super::stream::StreamingEngine`])
/// instantiate one and drive it through checkpoint/resume/snapshot; with
/// an [`ElasticConfig`] its `poll` additionally spawns and retires
/// workers mid-convergence.
pub struct WorkerPool {
    /// the fabric-management face of whichever transport
    /// `cfg.transport` selected (in-process bus or loopback TCP wire)
    hub: Box<dyn TransportHub<WorkerMsg>>,
    table: Arc<OwnershipTable>,
    state: Arc<MonitorState>,
    problem: Arc<FixedPointProblem>,
    cfg: DistributedConfig,
    metrics: Arc<MetricSet>,
    /// index = pid; None = vacant (never spawned, or retired)
    slots: Vec<Option<WorkerHandle>>,
    elastic: Option<ElasticState>,
    stats: PoolStats,
    epoch: u64,
}

impl WorkerPool {
    /// Spawn the initial K workers over `cfg.partition`.
    pub fn new(problem: Arc<FixedPointProblem>, cfg: DistributedConfig) -> Result<WorkerPool> {
        let k = cfg.partition.k();
        let cap = cfg
            .elastic
            .as_ref()
            .map(|e| e.max_workers.max(k))
            .unwrap_or(k);
        let state = MonitorState::with_capacity(k, cap);
        let names: Vec<&'static str> = WORKER_METRICS
            .iter()
            .chain(POOL_METRICS)
            .chain(QUERY_METRICS.iter())
            .copied()
            .collect();
        let (endpoints, hub, metrics) = fabric::<WorkerMsg>(
            cfg.transport,
            k,
            &BusConfig {
                latency: cfg.latency,
                seed: cfg.seed,
                flush: cfg.wire_flush,
            },
            &names,
        )?;
        let table = OwnershipTable::new(cfg.partition.clone());
        let elastic = cfg.elastic.as_ref().map(|e| ElasticState {
            cfg: e.clone(),
            last_counts: vec![0; cap],
            last_decision: Instant::now(),
            idle_since: vec![None; cap],
            min_total: cfg.tol * 100.0,
            ops: 0,
        });
        let mut pool = WorkerPool {
            hub,
            table,
            state,
            problem,
            cfg,
            metrics,
            slots: Vec::with_capacity(cap),
            elastic,
            stats: PoolStats {
                peak_live: k,
                live: k,
                ..Default::default()
            },
            epoch: 0,
        };
        for ep in endpoints {
            let handle = pool.spawn_thread(ep);
            pool.slots.push(Some(handle));
        }
        pool.metrics.set("pool_live", k as u64);
        pool.metrics.set("pool_peak_live", k as u64);
        Ok(pool)
    }

    /// Start one worker thread over an already-registered endpoint. The
    /// ownership table must already cover its PID (a vacant part is fine
    /// — the core starts with an empty Ω and adopts via handoff).
    fn spawn_thread(&mut self, ep: Box<dyn Transport<WorkerMsg>>) -> WorkerHandle {
        let pid = ep.id();
        let mut core = WorkerCore::new(
            pid,
            ep,
            self.problem.clone(),
            self.table.clone(),
            self.state.clone(),
            self.cfg.clone(),
        );
        if self.epoch > 0 {
            // a worker spawned mid-stream joins the CURRENT epoch: empty
            // owned set ⇒ empty fluid slice; the handoff that populates
            // it carries epoch-tagged state
            core.enter_epoch(self.epoch, self.problem.clone(), Vec::new(), None);
        }
        let (tx, rx) = channel::<Ctrl>();
        let state = self.state.clone();
        let worker = PoolWorker {
            core,
            ctrl: rx,
            state,
            rebase_ack: None,
        };
        let pin_cores = self.cfg.pin_cores;
        WorkerHandle {
            ctrl: tx,
            handle: std::thread::spawn(move || {
                if pin_cores {
                    // best-effort affinity from inside the spawned thread:
                    // pid % cores spreads elastic spawns across distinct
                    // cores (DESIGN.md §9); failure leaves the thread
                    // wherever the scheduler had it
                    let cores = std::thread::available_parallelism()
                        .map(|c| c.get())
                        .unwrap_or(1);
                    let _ = crate::perf::pin_to_core(pid % cores);
                }
                worker.run()
            }),
        }
    }

    // ------------------------------------------------------------------
    // engine-facing plumbing

    pub fn table(&self) -> &Arc<OwnershipTable> {
        &self.table
    }

    pub fn state(&self) -> &Arc<MonitorState> {
        &self.state
    }

    pub fn metrics(&self) -> &Arc<MetricSet> {
        &self.metrics
    }

    pub fn monitor(&self) -> BusMonitor {
        self.hub.monitor()
    }

    pub fn stats(&self) -> PoolStats {
        self.stats
    }

    /// PIDs currently backed by a worker thread.
    pub fn live_pids(&self) -> Vec<usize> {
        (0..self.slots.len())
            .filter(|&p| self.slots[p].is_some())
            .collect()
    }

    /// Ask every live worker to pause and report `(pid, Ω, H)`.
    pub fn checkpoint(&self) -> Result<Vec<(usize, Vec<usize>, Vec<f64>)>> {
        self.collect(|reply| Ctrl::Checkpoint { reply })
    }

    /// Read every live worker's `(pid, Ω, H)` without pausing it.
    pub fn snapshot(&self) -> Result<Vec<(usize, Vec<usize>, Vec<f64>)>> {
        self.collect(|reply| Ctrl::Snapshot { reply })
    }

    fn collect(
        &self,
        make: impl Fn(Sender<(usize, Vec<usize>, Vec<f64>)>) -> Ctrl,
    ) -> Result<Vec<(usize, Vec<usize>, Vec<f64>)>> {
        let (tx, rx) = channel();
        let mut expect = 0usize;
        for slot in self.slots.iter().flatten() {
            slot.ctrl
                .send(make(tx.clone()))
                .map_err(|_| DiterError::Coordinator("pool worker gone".into()))?;
            expect += 1;
        }
        drop(tx);
        let mut out = Vec::with_capacity(expect);
        for _ in 0..expect {
            out.push(
                rx.recv_timeout(Duration::from_secs(30))
                    .map_err(|_| DiterError::Coordinator("pool worker reply timed out".into()))?,
            );
        }
        Ok(out)
    }

    /// Resume every checkpointed worker into `epoch` with its rebased
    /// fluid slice. Also retargets the pool's own problem handle so
    /// workers spawned later join the right epoch.
    pub fn resume(
        &mut self,
        epoch: u64,
        problem: Arc<FixedPointProblem>,
        slices: Vec<(usize, Vec<f64>)>,
        dirty: Option<Arc<Vec<usize>>>,
    ) -> Result<()> {
        self.epoch = epoch;
        self.problem = problem.clone();
        for (pid, f_slice) in slices {
            let slot = self.slots[pid]
                .as_ref()
                .ok_or_else(|| DiterError::Coordinator(format!("no worker at pid {pid}")))?;
            slot.ctrl
                .send(Ctrl::Resume {
                    epoch,
                    problem: problem.clone(),
                    f_slice,
                    dirty: dirty.clone(),
                })
                .map_err(|_| DiterError::Coordinator("pool worker gone".into()))?;
        }
        Ok(())
    }

    /// Drive a V1-style **local** epoch transition: broadcast the
    /// mutation delta to every live worker and wait until each one has
    /// exchanged its halo and entered `epoch`. Workers never pause — the
    /// coordinator's wait here is for monitor sanity (convergence must
    /// not be judged while fluid deltas are still unapplied), not a
    /// barrier between workers: each worker proceeds the moment its own
    /// halo values arrive, independent of its peers' progress.
    pub fn rebase_local(
        &mut self,
        epoch: u64,
        problem: Arc<FixedPointProblem>,
        dirty: Arc<Vec<usize>>,
    ) -> Result<()> {
        self.epoch = epoch;
        self.problem = problem.clone();
        let (tx, rx) = channel::<usize>();
        let mut expect = 0usize;
        for slot in self.slots.iter().flatten() {
            slot.ctrl
                .send(Ctrl::RebaseLocal {
                    epoch,
                    problem: problem.clone(),
                    dirty: dirty.clone(),
                    reply: tx.clone(),
                })
                .map_err(|_| DiterError::Coordinator("pool worker gone".into()))?;
            expect += 1;
        }
        drop(tx);
        for _ in 0..expect {
            rx.recv_timeout(Duration::from_secs(30)).map_err(|_| {
                DiterError::Coordinator("local rebase ack timed out (halo exchange wedged)".into())
            })?;
        }
        Ok(())
    }

    /// Shut every worker down and return their final `(Ω, H)` pairs.
    /// Shutdown is broadcast to ALL workers before any join: a worker's
    /// drain loop only quiesces once its peers stop producing fluid at
    /// it, so stopping them one-by-one would serialize the exit (and, on
    /// an unconverged run, bounce parcels off already-joined workers).
    pub fn finish(mut self) -> Result<Vec<(Vec<usize>, Vec<f64>)>> {
        for slot in self.slots.iter().flatten() {
            let _ = slot.ctrl.send(Ctrl::Shutdown);
        }
        let mut out = Vec::new();
        for slot in self.slots.iter_mut() {
            if let Some(h) = slot.take() {
                out.push(
                    h.handle
                        .join()
                        .map_err(|_| DiterError::Coordinator("pool worker panicked".into()))?,
                );
            }
        }
        Ok(out)
    }

    // ------------------------------------------------------------------
    // the elastic scheduler

    /// One scheduler tick, called from the engine's monitor loop with the
    /// currently-observed total fluid. Completes pending lifecycle
    /// transitions, then (at most once per interval) starts a new one:
    /// spawn for a straggler, shed when at capacity, retire the idle.
    /// Returns true when a lifecycle operation started or completed.
    pub fn poll(&mut self, total: f64) -> bool {
        if self.elastic.is_none() || self.table.is_frozen() {
            return false;
        }
        // one liveness snapshot per tick (this runs every monitor poll);
        // the transition helpers keep it in sync with their writes
        let mut states = self.table.liveness_states();
        let mut acted = self.promote_spawning(&mut states);
        acted |= self.complete_draining(&mut states);
        let (interval, max_ops, min_workers, max_workers, min_total) = {
            let es = self.elastic.as_ref().expect("checked above");
            (
                es.cfg.interval,
                es.cfg.max_ops,
                es.cfg.min_workers,
                es.cfg.max_workers,
                es.min_total,
            )
        };
        {
            let es = self.elastic.as_ref().expect("checked above");
            if es.last_decision.elapsed() < interval || es.ops >= max_ops {
                return acted;
            }
        }
        // measure the window
        let counts = self.state.update_counts();
        let backlog = self.state.published_values();
        let k = self.table.partition().k();
        let deltas: Vec<u64> = {
            let es = self.elastic.as_mut().expect("checked above");
            let deltas = counts
                .iter()
                .zip(&es.last_counts)
                .map(|(now, base)| now.saturating_sub(*base))
                .collect();
            es.last_counts = counts;
            es.last_decision = Instant::now();
            deltas
        };
        self.track_idleness(&states, &deltas, &backlog);
        // a transition in flight (or an unsettled ownership move) blocks
        // new decisions: measurements straddling a migration are noise,
        // and the single-transition-at-a-time rule keeps the state
        // machine trivially serializable
        if states
            .iter()
            .any(|s| matches!(s, PidState::Spawning | PidState::Draining))
            || !self.table.all_acked(self.table.version())
            || self.table.handoffs_inflight() > 0
        {
            return acted;
        }
        let live = self.stats.live;
        // 1. retire: a worker idle past the window hands its Ω away —
        //    this is the merge-side policy from the ROADMAP (regrouping
        //    persistently-idle PIDs frees a core)
        if live > min_workers {
            if let Some((pid, absorber)) = self.pick_retire(&states, &deltas) {
                return self.retire(pid, absorber) || acted;
            }
        }
        // 2. spawn / shed: a persistent straggler sheds half its Ω — to a
        //    brand-new worker while there is headroom, else to the
        //    fastest existing peer (the PR 2 fixed-pool rebalance)
        if total.is_finite() && total > min_total {
            if let Some((straggler, fastest)) = self.pick_straggler(&states, &deltas, &backlog, k)
            {
                if live < max_workers {
                    return self.spawn_split(straggler).is_ok() || acted;
                }
                if let Some(fastest) = fastest {
                    return self.shed(straggler, fastest) || acted;
                }
            }
        }
        acted
    }

    /// Spawning → Live once the worker acked the version that routed
    /// coordinates at it (its handoff may still be flying — that's fine,
    /// Live only means "fully registered and syncing"). Mirrors its
    /// writes into the caller's liveness snapshot.
    fn promote_spawning(&mut self, states: &mut [PidState]) -> bool {
        let v = self.table.version();
        let mut acted = false;
        for pid in 0..states.len() {
            if states[pid] == PidState::Spawning && self.table.acked_version(pid) >= v {
                self.table.set_liveness(pid, PidState::Live);
                states[pid] = PidState::Live;
                acted = true;
            }
        }
        acted
    }

    /// Draining → Retired once the drain version is acked everywhere and
    /// no handoff slice is in flight: deregister the endpoint (stale
    /// senders now fail fast and re-route), then stop and join the
    /// thread — its forwarding exit path drains anything already queued.
    fn complete_draining(&mut self, states: &mut [PidState]) -> bool {
        let v = self.table.version();
        let mut acted = false;
        for pid in 0..states.len() {
            if states[pid] != PidState::Draining {
                continue;
            }
            if !self.table.all_acked(v) || self.table.handoffs_inflight() > 0 {
                continue;
            }
            self.hub.remove_endpoint(pid);
            if let Some(h) = self.slots[pid].take() {
                let _ = h.ctrl.send(Ctrl::Shutdown);
                let _ = h.handle.join();
            }
            self.table.set_liveness(pid, PidState::Retired);
            states[pid] = PidState::Retired;
            // the slot's published share is authoritatively zero now —
            // aggregate and per-query-lane alike (the drain forwarded
            // every lane's fluid before the endpoint came down)
            self.state.publish(pid, 0.0);
            if let Some(qs) = self.cfg.queries.as_ref() {
                qs.zero_published_pid(pid);
            }
            self.stats.retired += 1;
            self.stats.live -= 1;
            self.metrics.incr("pool_retired");
            self.metrics.set("pool_live", self.stats.live as u64);
            acted = true;
        }
        acted
    }

    /// Update per-slot idle clocks: idle = no updates this window AND no
    /// published backlog. A fluid-starved worker is idle, not slow — the
    /// same distinction `plan_rebalance` draws, inverted.
    fn track_idleness(&mut self, states: &[PidState], deltas: &[u64], backlog: &[f64]) {
        let es = self.elastic.as_mut().unwrap();
        let tol = self.cfg.tol;
        for pid in 0..es.idle_since.len() {
            let live = states.get(pid) == Some(&PidState::Live);
            let idle = live && deltas[pid] == 0 && backlog[pid] <= tol;
            if !idle {
                es.idle_since[pid] = None;
            } else if es.idle_since[pid].is_none() {
                es.idle_since[pid] = Some(Instant::now());
            }
        }
    }

    /// The straggler criterion over live, occupied parts (vacant slots
    /// must not drag the median down): lowest per-coordinate rate below
    /// spawn_threshold × median, holding fluid, big enough to split.
    /// Also returns the fastest live peer (the shed target at capacity).
    fn pick_straggler(
        &self,
        states: &[PidState],
        deltas: &[u64],
        backlog: &[f64],
        k: usize,
    ) -> Option<(usize, Option<usize>)> {
        let es = self.elastic.as_ref().unwrap();
        let part = self.table.partition();
        let mut rates: Vec<(usize, f64)> = Vec::new();
        for pid in 0..k {
            if states.get(pid) != Some(&PidState::Live) || part.part(pid).is_empty() {
                continue;
            }
            rates.push((pid, deltas[pid] as f64 / part.part(pid).len() as f64));
        }
        if rates.len() < 2 {
            return None;
        }
        let mut sorted: Vec<f64> = rates.iter().map(|r| r.1).collect();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = sorted[rates.len() / 2];
        if median <= 0.0 {
            return None;
        }
        let &(slowest, slow_rate) = rates
            .iter()
            .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())?;
        // same idle floor as track_idleness: a worker whose residual is
        // below tol is starved/drained, not slow — a window with zero
        // updates and ~1e-14 backlog must not read as a straggler
        if slow_rate >= es.cfg.spawn_threshold * median
            || backlog[slowest] <= self.cfg.tol
            || part.part(slowest).len() < 2 * es.cfg.min_part
        {
            return None;
        }
        let fastest = rates
            .iter()
            .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
            .map(|r| r.0)
            .filter(|&f| f != slowest);
        Some((slowest, fastest))
    }

    /// The retire criterion: the longest-idle live worker past the
    /// policy window, absorbed by the busiest other live worker (its
    /// demonstrated capacity makes it the cheapest place to park an
    /// already-drained Ω).
    fn pick_retire(&self, states: &[PidState], deltas: &[u64]) -> Option<(usize, usize)> {
        let es = self.elastic.as_ref().expect("elastic poll only");
        let now = Instant::now();
        let retiree = (0..es.idle_since.len())
            .filter(|&p| states.get(p) == Some(&PidState::Live))
            .filter_map(|p| {
                es.idle_since[p]
                    .filter(|t| now.duration_since(*t) >= es.cfg.retire_idle)
                    .map(|t| (p, t))
            })
            .min_by_key(|&(_, t)| t)
            .map(|(p, _)| p)?;
        let absorber = (0..states.len())
            .filter(|&p| p != retiree && states[p] == PidState::Live)
            .max_by_key(|&p| deltas.get(p).copied().unwrap_or(0))?;
        Some((retiree, absorber))
    }

    /// Spawn a new live worker and hand it the cut-aware half of
    /// `from`'s Ω. Public so tests (and future policies) can drive the
    /// mechanics directly; the policy path is [`WorkerPool::poll`].
    pub fn spawn_split(&mut self, from: usize) -> Result<usize> {
        let cap = self.state.capacity();
        // prefer reusing a retired slot; else append, bounded by capacity
        let states = self.table.liveness_states();
        let vacant = states.iter().position(|s| *s == PidState::Retired);
        let pid = match vacant {
            Some(p) => p,
            None => {
                let p = self.slots.len();
                if p >= cap {
                    return Err(DiterError::Coordinator(format!(
                        "worker pool at capacity ({cap})"
                    )));
                }
                p
            }
        };
        // 1. the mailbox must exist before any ownership map routes
        //    fluid at the new PID
        let ep = self.hub.add_endpoint(pid)?;
        // 2. widen the table (new slots pre-acked ⇒ quiescence stays
        //    sound while the worker boots) and give the partition a
        //    vacant part for the PID if it does not have one yet
        if pid >= self.table.width() {
            self.table.grow(pid + 1);
        } else {
            self.table.reactivate(pid);
        }
        let part = self.table.partition();
        if pid >= part.k() {
            let grown = part.with_k(pid + 1)?;
            if self.table.install_elastic(grown).is_none() {
                // frozen mid-spawn cannot happen from the poll path (the
                // engine freezes only on its own thread), but fail safe:
                // withdraw the endpoint and report
                self.hub.remove_endpoint(pid);
                self.table.set_liveness(pid, PidState::Retired);
                return Err(DiterError::Coordinator("table frozen during spawn".into()));
            }
        }
        // 3. start the worker: empty Ω, current epoch
        let handle = self.spawn_thread(ep);
        if pid == self.slots.len() {
            self.slots.push(Some(handle));
        } else {
            self.slots[pid] = Some(handle);
        }
        // 4. route the straggler's half at it — the handoff machinery
        //    does the rest
        let part = self.table.partition();
        let coords = choose_shed_half(&part, from, pid, Some(self.problem.matrix()));
        let next = part.transfer_elastic(&coords, pid)?;
        if self.table.install_elastic(next).is_none() {
            return Err(DiterError::Coordinator("table frozen during spawn".into()));
        }
        if let Some(es) = self.elastic.as_mut() {
            es.ops += 1;
        }
        self.stats.spawned += 1;
        self.stats.live += 1;
        self.stats.peak_live = self.stats.peak_live.max(self.stats.live);
        self.metrics.incr("pool_spawned");
        self.metrics.set("pool_live", self.stats.live as u64);
        self.metrics.set("pool_peak_live", self.stats.peak_live as u64);
        Ok(pid)
    }

    /// Begin retiring `pid`: move its whole Ω to `absorber` and mark it
    /// Draining. The retirement completes asynchronously in
    /// [`WorkerPool::poll`] (or [`WorkerPool::settle`]) once the drain
    /// quiesced. Public for tests and direct policy drivers.
    pub fn retire(&mut self, pid: usize, absorber: usize) -> bool {
        if pid == absorber || self.slots.get(pid).map(Option::is_none).unwrap_or(true) {
            return false;
        }
        let part = self.table.partition();
        let coords = part.part(pid).to_vec();
        let Ok(next) = part.transfer_elastic(&coords, absorber) else {
            return false;
        };
        self.table.set_liveness(pid, PidState::Draining);
        if self.table.install_elastic(next).is_none() {
            self.table.set_liveness(pid, PidState::Live);
            return false;
        }
        if let Some(es) = self.elastic.as_mut() {
            es.ops += 1;
            es.idle_since[pid] = None;
        }
        true
    }

    /// Drive pending lifecycle transitions to completion (bounded wait).
    /// Used by tests and by engines that must quiesce the pool outside
    /// the poll loop.
    pub fn settle(&mut self, deadline: Duration) -> bool {
        let until = Instant::now() + deadline;
        loop {
            let mut states = self.table.liveness_states();
            self.promote_spawning(&mut states);
            self.complete_draining(&mut states);
            if !states
                .iter()
                .any(|s| matches!(s, PidState::Spawning | PidState::Draining))
            {
                return true;
            }
            if Instant::now() >= until {
                return false;
            }
            std::thread::sleep(Duration::from_micros(200));
        }
    }

    /// Shed half of `from`'s Ω to `to` on the fixed pool (at capacity).
    fn shed(&mut self, from: usize, to: usize) -> bool {
        let part = self.table.partition();
        let coords = choose_shed_half(&part, from, to, Some(self.problem.matrix()));
        let Ok(next) = part.transfer_elastic(&coords, to) else {
            return false;
        };
        if self.table.install_elastic(next).is_none() {
            return false;
        }
        if let Some(es) = self.elastic.as_mut() {
            es.ops += 1;
        }
        self.stats.sheds += 1;
        self.metrics.set("handoffs_planned", self.stats.sheds);
        true
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        // dropping the control senders terminates the worker loops; the
        // threads unwind on their own (finish() joins them explicitly)
        for slot in self.slots.iter().flatten() {
            let _ = slot.ctrl.send(Ctrl::Shutdown);
        }
    }
}

/// One persistent PID worker: the shared core plus pool control. Exits
/// on `Ctrl::Shutdown`, a disconnected control channel, or the monitor's
/// stop flag (the one-shot engines stop the whole pool at once).
struct PoolWorker {
    core: WorkerCore,
    ctrl: Receiver<Ctrl>,
    state: Arc<MonitorState>,
    /// (target epoch, ack channel) of an in-flight local rebase — sent
    /// once the core's halo state machine has entered the epoch
    rebase_ack: Option<(u64, Sender<usize>)>,
}

impl PoolWorker {
    fn run(mut self) -> (Vec<usize>, Vec<f64>) {
        loop {
            if self.state.should_stop() {
                break;
            }
            self.maybe_ack_rebase();
            match self.ctrl.try_recv() {
                Ok(c) => {
                    if !self.handle_ctrl(c) {
                        break;
                    }
                    continue; // drain further control messages first
                }
                Err(TryRecvError::Empty) => {}
                Err(TryRecvError::Disconnected) => break,
            }
            let (got_fluid, r_k) = self.core.step();
            if !got_fluid && r_k == 0.0 && self.core.is_drained() {
                std::thread::sleep(Duration::from_micros(50));
            }
        }
        self.core.finish()
    }

    /// Ack a completed local epoch entry back to the coordinator. The
    /// entry happens inside `step` (when the last awaited halo arrives)
    /// or inside `handle_ctrl` (nothing awaited), so the check runs every
    /// loop iteration.
    fn maybe_ack_rebase(&mut self) {
        let entered =
            matches!(&self.rebase_ack, Some((target, _)) if self.core.epoch() >= *target);
        if !entered {
            return;
        }
        if let Some((_, tx)) = self.rebase_ack.take() {
            let _ = tx.send(self.core.pid());
        }
    }

    fn reply_state(&self, reply: &Sender<(usize, Vec<usize>, Vec<f64>)>) {
        let _ = reply.send((
            self.core.pid(),
            self.core.owned().to_vec(),
            self.core.h().to_vec(),
        ));
    }

    /// Returns false when the worker must terminate.
    fn handle_ctrl(&mut self, c: Ctrl) -> bool {
        match c {
            Ctrl::Snapshot { reply } => {
                self.reply_state(&reply);
                true
            }
            Ctrl::Shutdown => false,
            Ctrl::Checkpoint { reply } => {
                self.reply_state(&reply);
                // paused: block until the coordinator resumes us
                loop {
                    match self.ctrl.recv() {
                        Ok(Ctrl::Resume {
                            epoch,
                            problem,
                            f_slice,
                            dirty,
                        }) => {
                            self.core.enter_epoch(
                                epoch,
                                problem,
                                f_slice,
                                dirty.as_ref().map(|d| d.as_slice()),
                            );
                            return true;
                        }
                        Ok(Ctrl::Snapshot { reply }) | Ok(Ctrl::Checkpoint { reply }) => {
                            self.reply_state(&reply);
                        }
                        Ok(Ctrl::RebaseLocal { .. }) => {
                            // the two protocols never mix within a run: a
                            // checkpoint pause (gather) cannot receive a
                            // local transition
                            debug_assert!(false, "RebaseLocal during a checkpoint pause");
                        }
                        Ok(Ctrl::Shutdown) | Err(_) => return false,
                    }
                }
            }
            Ctrl::RebaseLocal {
                epoch,
                problem,
                dirty,
                reply,
            } => {
                self.core.begin_rebase_local(epoch, problem, dirty);
                // acked from the run loop once the halo exchange settles
                self.rebase_ack = Some((epoch, reply));
                true
            }
            Ctrl::Resume {
                epoch,
                problem,
                f_slice,
                dirty,
            } => {
                // resume without a checkpoint (defensive: coordinator
                // always checkpoints first, but the transition is safe
                // from any state)
                self.core.enter_epoch(
                    epoch,
                    problem,
                    f_slice,
                    dirty.as_ref().map(|d| d.as_slice()),
                );
                true
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{pagerank_system, power_law_web_graph};
    use crate::linalg::vec_ops::norm1;
    use crate::partition::Partition;

    fn pagerank_problem(n: usize, seed: u64) -> Arc<FixedPointProblem> {
        let g = power_law_web_graph(n, 5, 0.1, seed);
        let sys = pagerank_system(&g, 0.85, true).unwrap();
        Arc::new(FixedPointProblem::new(sys.matrix.clone(), sys.b.clone()).unwrap())
    }

    fn gather(pairs: &[(usize, Vec<usize>, Vec<f64>)], n: usize) -> Vec<f64> {
        let mut x = vec![0.0; n];
        for (_, coords, vals) in pairs {
            for (t, &i) in coords.iter().enumerate() {
                x[i] = vals[t];
            }
        }
        x
    }

    #[test]
    fn pool_spawn_and_retire_lifecycle() {
        let n = 60;
        let problem = pagerank_problem(n, 3);
        let cfg = DistributedConfig::new(Partition::contiguous(n, 2).unwrap())
            .with_tol(1e-10)
            .with_seed(3)
            .with_elastic(ElasticConfig {
                max_workers: 4,
                ..Default::default()
            });
        let mut pool = WorkerPool::new(problem, cfg).unwrap();
        assert_eq!(pool.live_pids(), vec![0, 1]);
        // live split: a third worker absorbs half of PID 0's Ω
        let pid = pool.spawn_split(0).unwrap();
        assert_eq!(pid, 2);
        assert!(pool.settle(Duration::from_secs(5)), "spawn must settle");
        assert_eq!(pool.table.liveness(2), PidState::Live);
        assert_eq!(pool.stats().spawned, 1);
        assert_eq!(pool.stats().live, 3);
        let part = pool.table.partition();
        assert_eq!(part.k(), 3);
        assert!(!part.part(2).is_empty(), "the spawn took real ownership");
        // live merge: retire it again, absorbed by PID 1
        assert!(pool.retire(2, 1));
        assert!(pool.settle(Duration::from_secs(5)), "retire must settle");
        assert_eq!(pool.table.liveness(2), PidState::Retired);
        assert_eq!(pool.stats().retired, 1);
        assert_eq!(pool.stats().live, 2);
        assert!(pool.table.partition().part(2).is_empty());
        // respawn reuses the vacant slot
        let pid = pool.spawn_split(1).unwrap();
        assert_eq!(pid, 2, "retired slot is recycled");
        assert!(pool.settle(Duration::from_secs(5)));
        assert_eq!(pool.stats().live, 3);
        // the exact cover survived the whole dance, and so did the fluid:
        // let the diffusion run out, then the gathered solution is the
        // fixed point with unit mass
        let state = pool.state().clone();
        let mon = pool.monitor();
        let deadline = Instant::now() + Duration::from_secs(30);
        loop {
            let total = state.published_total() + mon.inflight_or_zero();
            if (total < 1e-10 && mon.undelivered() == 0) || Instant::now() >= deadline {
                break;
            }
            std::thread::sleep(Duration::from_micros(300));
        }
        state.request_stop();
        let pairs = pool.finish().unwrap();
        let mut x = vec![0.0; n];
        let mut covered = 0;
        for (owned, vals) in &pairs {
            for (t, &i) in owned.iter().enumerate() {
                x[i] = vals[t];
                covered += 1;
            }
        }
        assert_eq!(covered, n, "exact cover after spawn/retire/respawn");
        assert!(
            (norm1(&x) - 1.0).abs() < 1e-7,
            "PageRank mass conserved: ‖x‖₁ = {}",
            norm1(&x)
        );
    }

    #[test]
    fn snapshot_covers_all_live_workers() {
        let n = 40;
        let problem = pagerank_problem(n, 9);
        let cfg = DistributedConfig::new(Partition::contiguous(n, 3).unwrap())
            .with_tol(1e-9)
            .with_seed(9);
        let pool = WorkerPool::new(problem, cfg).unwrap();
        let pairs = pool.snapshot().unwrap();
        assert_eq!(pairs.len(), 3);
        let covered: usize = pairs.iter().map(|(_, c, _)| c.len()).sum();
        assert_eq!(covered, n);
        let _ = gather(&pairs, n);
        pool.state().request_stop();
        pool.finish().unwrap();
    }
}
