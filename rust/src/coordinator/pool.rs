//! The elastic worker pool: one scheduler that owns every PID's
//! lifecycle — spawn, run, drain, retire — and the control channel both
//! engines drive it through.
//!
//! The paper's §4.3 speed adaptation has two halves. The *fixed-pool*
//! half (PR 2) moves ownership between a constant K workers; this module
//! adds the *elastic* half: the PID count itself tracks the workload
//! (arXiv 1203.1715 evaluates exactly this dynamic-partition policy, and
//! the flexible-communication results of arXiv 2210.04626 justify
//! convergence with endpoints that appear and disappear mid-iteration).
//!
//! ## Lifecycle (DESIGN.md §6)
//!
//! ```text
//!            add_endpoint        handoff folded
//! (vacant) ──────────────▶ Spawning ────────────▶ Live
//!                                                  │ drain install
//!                                                  ▼
//!            remove_endpoint + join            Draining
//! (vacant) ◀──────────────────────── Retired ◀─────┘
//!                                        acked ∧ inflight == 0
//! ```
//!
//! **Spawn** (a persistent straggler, PID headroom available): reserve a
//! slot → register its bus endpoint → widen the [`OwnershipTable`] →
//! start the worker on an **empty** `LocalSystem` (it enters the current
//! epoch with a zero-length fluid slice) → install the cut-aware half of
//! the straggler's Ω. The straggler itself ships the `(H, B, F)` slice
//! over the PR 2 [`super::worker::Handoff`] machinery; the new worker's
//! adopt-from-empty is just the ordinary handoff fold.
//!
//! **Retire** (a worker idle past the policy window): install a
//! transfer of its whole Ω to an absorber (the part goes empty, the slot
//! stays) → wait until the drain acked and no handoff slice is in flight
//! → deregister the endpoint **first**, then shut the thread down. The
//! order matters: after `remove_endpoint` returns, stale senders fail
//! fast and re-route, while everything already queued is drained by the
//! worker's forwarding exit path ([`WorkerCore::finish`]) — so a retire
//! mid-convergence conserves every unit of fluid.
//!
//! Both transitions run **asynchronously** against the diffusion: the
//! pool installs an ownership version and lets the workers migrate state
//! themselves; `poll` completes the lifecycle transitions on later
//! ticks. All pool operations happen on the engine's monitor thread, so
//! they are serial with epoch rebases (which freeze the table anyway).

use std::collections::HashMap;
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::adaptive::choose_shed_half;
use super::monitor::MonitorState;
use super::query::QUERY_METRICS;
use super::update;
use super::worker::{WorkerCore, WorkerMsg, WORKER_METRICS};
use super::DistributedConfig;
use crate::error::{DiterError, Result};
use crate::metrics::MetricSet;
use crate::partition::{OwnershipTable, PidState};
use crate::solver::FixedPointProblem;
use crate::transport::{fabric, BusConfig, BusMonitor, Transport, TransportHub};

/// Pool gauges registered on top of the worker/bus metrics.
pub const POOL_METRICS: &[&str] = &[
    "pool_spawned",      // workers spawned at runtime
    "pool_retired",      // workers retired at runtime
    "pool_live",         // current live worker count (gauge)
    "pool_peak_live",    // high-water mark of live workers
    "pool_crashes",      // worker deaths detected (panic or kill)
    "pool_recoveries",   // dead slots respawned with restored state
    "pool_checkpoints",  // incremental H journal entries folded in
    "worker_stale_beats", // heartbeat-staleness observations (gauge)
];

/// Coordinator → worker control messages. Checkpoint/Snapshot replies
/// carry `(pid, held coords, H slice)` — with live repartitioning the
/// held range is dynamic, so the coordinates always travel with the data.
pub(crate) enum Ctrl {
    /// Pause, reply with the held range + H slice, wait for `Resume`.
    Checkpoint {
        reply: Sender<(usize, Vec<usize>, Vec<f64>)>,
    },
    /// New epoch: swap the matrix, reset the fluid slice, keep H.
    /// `dirty` lists the matrix columns that changed since the previous
    /// epoch (ascending) when the incremental build knows them — workers
    /// patch their `LocalSystem` instead of rebuilding it.
    Resume {
        epoch: u64,
        problem: Arc<FixedPointProblem>,
        f_slice: Vec<f64>,
        dirty: Option<Arc<Vec<usize>>>,
    },
    /// Non-pausing read of the held range + H (worker keeps running).
    Snapshot {
        reply: Sender<(usize, Vec<usize>, Vec<f64>)>,
    },
    /// V1-style local epoch transition ([`super::RebaseMode::Local`]):
    /// the worker freezes its owned dirty columns, exchanges halo H
    /// values with its peers over the bus, rebases its own fluid slice in
    /// place, and sends its pid on `reply` once it has entered `epoch` —
    /// all without pausing the diffusion of non-dirty fluid. No
    /// checkpoint, no scatter.
    RebaseLocal {
        epoch: u64,
        problem: Arc<FixedPointProblem>,
        /// the mutation delta: matrix columns that changed, ascending
        dirty: Arc<Vec<usize>>,
        reply: Sender<usize>,
    },
    /// Terminate; the final (Ω, H) comes back through the join handle.
    Shutdown,
    /// Incremental checkpoint: reply `(pid, basis epoch, full?, coords,
    /// lane-blocked H)` from [`WorkerCore::journal`] without pausing —
    /// full snapshot on a basis change, dirty-slot delta otherwise.
    Journal {
        reply: Sender<(usize, u64, bool, Vec<usize>, Vec<f64>)>,
    },
    /// Crash recovery: reconcile transport state with the death of
    /// `pid` ([`crate::transport::Transport::peer_reset`]), ack with own
    /// pid. Sent while the worker is paused at the recovery barrier.
    Reconcile { pid: usize, reply: Sender<usize> },
    /// Chaos hook: die like a crash — exit immediately WITHOUT the
    /// forwarding drain, leaving queued parcels and unacked retention
    /// behind exactly as a panicking thread would.
    Die,
}

/// Elastic policy knobs (`--max-workers`, `--spawn-threshold`,
/// `--retire-idle-ms` on the CLI).
#[derive(Clone, Debug)]
pub struct ElasticConfig {
    /// hard cap on concurrently-live workers (bus/table/monitor capacity
    /// is pre-sized to this)
    pub max_workers: usize,
    /// spawn a worker for a straggler whose per-coordinate rate is below
    /// this fraction of the median (the §4.3 split criterion)
    pub spawn_threshold: f64,
    /// retire a worker continuously idle (no updates, no backlog) for
    /// this long
    pub retire_idle: Duration,
    /// decision window: rates are measured and at most one lifecycle
    /// operation is started per interval
    pub interval: Duration,
    /// never split a part below 2× this many coordinates
    pub min_part: usize,
    /// never retire below this many live workers
    pub min_workers: usize,
    /// hard cap on lifecycle operations per run (runaway guard)
    pub max_ops: u64,
}

impl Default for ElasticConfig {
    fn default() -> Self {
        Self {
            max_workers: 8,
            spawn_threshold: 0.5,
            retire_idle: Duration::from_millis(250),
            interval: Duration::from_millis(40),
            min_part: 2,
            min_workers: 1,
            max_ops: 64,
        }
    }
}

/// Lifecycle counters exposed to engines, the CLI stats block and the
/// elastic bench.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PoolStats {
    /// workers spawned at runtime (beyond the initial K)
    pub spawned: u64,
    /// workers retired at runtime
    pub retired: u64,
    /// ownership sheds installed by the pool (straggler relief when the
    /// pool is at max_workers)
    pub sheds: u64,
    /// high-water mark of concurrently-live workers
    pub peak_live: usize,
    /// live workers right now
    pub live: usize,
    /// worker deaths detected (panic or simulated kill)
    pub crashes: u64,
    /// dead slots respawned with restored H + reconstructed fluid
    pub recoveries: u64,
}

/// One PID slot's worker-side handles.
struct WorkerHandle {
    ctrl: Sender<Ctrl>,
    handle: JoinHandle<(Vec<usize>, Vec<f64>)>,
}

/// Coordinator-side store of one worker's last H checkpoint (DESIGN.md
/// §11). Assembled incrementally from [`Ctrl::Journal`] replies: a full
/// snapshot re-seats the basis, a delta patches rows in place. Any
/// stored H is a *valid* restore point — `F = B + (P − I)·H` holds for
/// every H, so staleness loses progress, never correctness.
struct Checkpoint {
    /// basis epoch — a delta only patches a same-epoch basis
    epoch: u64,
    /// coordinate → row index into `h`
    pos: HashMap<usize, usize>,
    /// lane-blocked H rows (row r = the coord with `pos[coord] == r`)
    h: Vec<f64>,
}

/// Elastic driver state (None on a fixed pool).
struct ElasticState {
    cfg: ElasticConfig,
    last_counts: Vec<u64>,
    last_decision: Instant,
    /// per-slot instant the worker was first observed idle (None = busy)
    idle_since: Vec<Option<Instant>>,
    /// below this much total fluid no spawn/shed fires (nearly drained —
    /// migrating buys nothing); retire stays allowed, that IS the win
    min_total: f64,
    ops: u64,
}

/// The worker-pool scheduler: owns the bus hub, the ownership table, the
/// monitor slots, and every worker thread. Both engines
/// ([`super::v2::solve_v2`] and [`super::stream::StreamingEngine`])
/// instantiate one and drive it through checkpoint/resume/snapshot; with
/// an [`ElasticConfig`] its `poll` additionally spawns and retires
/// workers mid-convergence.
pub struct WorkerPool {
    /// the fabric-management face of whichever transport
    /// `cfg.transport` selected (in-process bus or loopback TCP wire)
    hub: Box<dyn TransportHub<WorkerMsg>>,
    table: Arc<OwnershipTable>,
    state: Arc<MonitorState>,
    problem: Arc<FixedPointProblem>,
    cfg: DistributedConfig,
    metrics: Arc<MetricSet>,
    /// index = pid; None = vacant (never spawned, or retired)
    slots: Vec<Option<WorkerHandle>>,
    elastic: Option<ElasticState>,
    stats: PoolStats,
    epoch: u64,
    /// per-pid last H checkpoint (crash tolerance; empty when off)
    checkpoints: Vec<Option<Checkpoint>>,
    /// an outstanding non-blocking journal request: `(pid, reply rx)`,
    /// polled with `try_recv` on later ticks so the hot path never waits
    ckpt_pending: Option<(usize, Receiver<(usize, u64, bool, Vec<usize>, Vec<f64>)>)>,
    /// round-robin cursor: one worker is journaled per interval
    ckpt_rr: usize,
    last_checkpoint: Instant,
    /// pids whose death was detected but whose recovery has not
    /// completed yet (recovery retries across ticks on contention)
    dead_pending: Vec<usize>,
}

impl WorkerPool {
    /// Spawn the initial K workers over `cfg.partition`.
    pub fn new(problem: Arc<FixedPointProblem>, cfg: DistributedConfig) -> Result<WorkerPool> {
        let k = cfg.partition.k();
        let cap = cfg
            .elastic
            .as_ref()
            .map(|e| e.max_workers.max(k))
            .unwrap_or(k);
        let state = MonitorState::with_capacity(k, cap);
        let names: Vec<&'static str> = WORKER_METRICS
            .iter()
            .chain(POOL_METRICS)
            .chain(QUERY_METRICS.iter())
            .copied()
            .collect();
        let (endpoints, hub, metrics) = fabric::<WorkerMsg>(
            cfg.transport,
            k,
            &BusConfig {
                latency: cfg.latency,
                seed: cfg.seed,
                flush: cfg.wire_flush,
                // ack-release accounting only when crash tolerance is on:
                // the no-failure hot path stays byte-identical otherwise
                ack_release: cfg.crash_tolerant(),
            },
            &names,
        )?;
        let table = OwnershipTable::new(cfg.partition.clone());
        let elastic = cfg.elastic.as_ref().map(|e| ElasticState {
            cfg: e.clone(),
            last_counts: vec![0; cap],
            last_decision: Instant::now(),
            idle_since: vec![None; cap],
            min_total: cfg.tol * 100.0,
            ops: 0,
        });
        let mut pool = WorkerPool {
            hub,
            table,
            state,
            problem,
            cfg,
            metrics,
            slots: Vec::with_capacity(cap),
            elastic,
            stats: PoolStats {
                peak_live: k,
                live: k,
                ..Default::default()
            },
            epoch: 0,
            checkpoints: Vec::new(),
            ckpt_pending: None,
            ckpt_rr: 0,
            last_checkpoint: Instant::now(),
            dead_pending: Vec::new(),
        };
        for ep in endpoints {
            let handle = pool.spawn_thread(ep);
            pool.slots.push(Some(handle));
        }
        pool.metrics.set("pool_live", k as u64);
        pool.metrics.set("pool_peak_live", k as u64);
        Ok(pool)
    }

    /// Start one worker thread over an already-registered endpoint. The
    /// ownership table must already cover its PID (a vacant part is fine
    /// — the core starts with an empty Ω and adopts via handoff).
    fn spawn_thread(&mut self, ep: Box<dyn Transport<WorkerMsg>>) -> WorkerHandle {
        let pid = ep.id();
        let mut core = WorkerCore::new(
            pid,
            ep,
            self.problem.clone(),
            self.table.clone(),
            self.state.clone(),
            self.cfg.clone(),
        );
        if self.epoch > 0 {
            // a worker spawned mid-stream joins the CURRENT epoch: empty
            // owned set ⇒ empty fluid slice; the handoff that populates
            // it carries epoch-tagged state
            core.enter_epoch(self.epoch, self.problem.clone(), Vec::new(), None);
        }
        self.spawn_core(core)
    }

    /// Wrap an already-initialized core in its worker thread. Shared by
    /// the cold spawn path above and the crash-recovery respawn (which
    /// restores H and enters the new epoch before the thread starts).
    fn spawn_core(&mut self, core: WorkerCore) -> WorkerHandle {
        let pid = core.pid();
        let (tx, rx) = channel::<Ctrl>();
        let state = self.state.clone();
        let worker = PoolWorker {
            core,
            ctrl: rx,
            state,
            rebase_ack: None,
            killed: false,
        };
        let pin_cores = self.cfg.pin_cores;
        WorkerHandle {
            ctrl: tx,
            handle: std::thread::spawn(move || {
                if pin_cores {
                    // best-effort affinity from inside the spawned thread:
                    // pid % cores spreads elastic spawns across distinct
                    // cores (DESIGN.md §9); failure leaves the thread
                    // wherever the scheduler had it
                    let cores = std::thread::available_parallelism()
                        .map(|c| c.get())
                        .unwrap_or(1);
                    let _ = crate::perf::pin_to_core(pid % cores);
                }
                worker.run()
            }),
        }
    }

    // ------------------------------------------------------------------
    // engine-facing plumbing

    pub fn table(&self) -> &Arc<OwnershipTable> {
        &self.table
    }

    pub fn state(&self) -> &Arc<MonitorState> {
        &self.state
    }

    pub fn metrics(&self) -> &Arc<MetricSet> {
        &self.metrics
    }

    pub fn monitor(&self) -> BusMonitor {
        self.hub.monitor()
    }

    pub fn stats(&self) -> PoolStats {
        self.stats
    }

    /// The epoch the pool last resumed into. Recovery bumps it (the
    /// fence that obsoletes crash-era parcels), so engines re-sync
    /// their own counter through this before the next rebase.
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Chaos hook: make worker `pid` die like a crash — the thread exits
    /// without the forwarding drain, stranding queued parcels and unacked
    /// retention exactly as a panic would. Returns false for a vacant
    /// slot. Recovery happens on later `poll` ticks.
    pub fn kill(&self, pid: usize) -> bool {
        self.slots
            .get(pid)
            .and_then(Option::as_ref)
            .map(|h| h.ctrl.send(Ctrl::Die).is_ok())
            .unwrap_or(false)
    }

    /// PIDs currently backed by a worker thread.
    pub fn live_pids(&self) -> Vec<usize> {
        (0..self.slots.len())
            .filter(|&p| self.slots[p].is_some())
            .collect()
    }

    /// Ask every live worker to pause and report `(pid, Ω, H)`.
    pub fn checkpoint(&self) -> Result<Vec<(usize, Vec<usize>, Vec<f64>)>> {
        self.collect(|reply| Ctrl::Checkpoint { reply })
    }

    /// Read every live worker's `(pid, Ω, H)` without pausing it.
    pub fn snapshot(&self) -> Result<Vec<(usize, Vec<usize>, Vec<f64>)>> {
        self.collect(|reply| Ctrl::Snapshot { reply })
    }

    fn collect(
        &self,
        make: impl Fn(Sender<(usize, Vec<usize>, Vec<f64>)>) -> Ctrl,
    ) -> Result<Vec<(usize, Vec<usize>, Vec<f64>)>> {
        let (tx, rx) = channel();
        let mut expect = 0usize;
        for slot in self.slots.iter().flatten() {
            slot.ctrl
                .send(make(tx.clone()))
                .map_err(|_| DiterError::Coordinator("pool worker gone".into()))?;
            expect += 1;
        }
        drop(tx);
        let mut out = Vec::with_capacity(expect);
        for _ in 0..expect {
            out.push(
                rx.recv_timeout(Duration::from_secs(30))
                    .map_err(|_| DiterError::Coordinator("pool worker reply timed out".into()))?,
            );
        }
        Ok(out)
    }

    /// Resume every checkpointed worker into `epoch` with its rebased
    /// fluid slice. Also retargets the pool's own problem handle so
    /// workers spawned later join the right epoch.
    pub fn resume(
        &mut self,
        epoch: u64,
        problem: Arc<FixedPointProblem>,
        slices: Vec<(usize, Vec<f64>)>,
        dirty: Option<Arc<Vec<usize>>>,
    ) -> Result<()> {
        self.epoch = epoch;
        self.problem = problem.clone();
        for (pid, f_slice) in slices {
            let slot = self.slots[pid]
                .as_ref()
                .ok_or_else(|| DiterError::Coordinator(format!("no worker at pid {pid}")))?;
            slot.ctrl
                .send(Ctrl::Resume {
                    epoch,
                    problem: problem.clone(),
                    f_slice,
                    dirty: dirty.clone(),
                })
                .map_err(|_| DiterError::Coordinator("pool worker gone".into()))?;
        }
        Ok(())
    }

    /// Drive a V1-style **local** epoch transition: broadcast the
    /// mutation delta to every live worker and wait until each one has
    /// exchanged its halo and entered `epoch`. Workers never pause — the
    /// coordinator's wait here is for monitor sanity (convergence must
    /// not be judged while fluid deltas are still unapplied), not a
    /// barrier between workers: each worker proceeds the moment its own
    /// halo values arrive, independent of its peers' progress.
    pub fn rebase_local(
        &mut self,
        epoch: u64,
        problem: Arc<FixedPointProblem>,
        dirty: Arc<Vec<usize>>,
    ) -> Result<()> {
        self.epoch = epoch;
        self.problem = problem.clone();
        let (tx, rx) = channel::<usize>();
        let mut expect = 0usize;
        for slot in self.slots.iter().flatten() {
            slot.ctrl
                .send(Ctrl::RebaseLocal {
                    epoch,
                    problem: problem.clone(),
                    dirty: dirty.clone(),
                    reply: tx.clone(),
                })
                .map_err(|_| DiterError::Coordinator("pool worker gone".into()))?;
            expect += 1;
        }
        drop(tx);
        for _ in 0..expect {
            rx.recv_timeout(Duration::from_secs(30)).map_err(|_| {
                DiterError::Coordinator("local rebase ack timed out (halo exchange wedged)".into())
            })?;
        }
        Ok(())
    }

    /// Shut every worker down and return their final `(Ω, H)` pairs.
    /// Shutdown is broadcast to ALL workers before any join: a worker's
    /// drain loop only quiesces once its peers stop producing fluid at
    /// it, so stopping them one-by-one would serialize the exit (and, on
    /// an unconverged run, bounce parcels off already-joined workers).
    pub fn finish(mut self) -> Result<Vec<(Vec<usize>, Vec<f64>)>> {
        for slot in self.slots.iter().flatten() {
            let _ = slot.ctrl.send(Ctrl::Shutdown);
        }
        let mut out = Vec::new();
        for slot in self.slots.iter_mut() {
            if let Some(h) = slot.take() {
                out.push(
                    h.handle
                        .join()
                        .map_err(|_| DiterError::Coordinator("pool worker panicked".into()))?,
                );
            }
        }
        Ok(out)
    }

    // ------------------------------------------------------------------
    // the elastic scheduler

    /// One scheduler tick, called from the engine's monitor loop with the
    /// currently-observed total fluid. Completes pending lifecycle
    /// transitions, then (at most once per interval) starts a new one:
    /// spawn for a straggler, shed when at capacity, retire the idle.
    /// Returns true when a lifecycle operation started or completed.
    pub fn poll(&mut self, total: f64) -> bool {
        // crash detection/checkpointing/recovery run on EVERY poll —
        // before the elastic gate, so fixed pools are crash-tolerant too
        let mut acted = self.poll_crashes();
        if self.elastic.is_none() || self.table.is_frozen() {
            return acted;
        }
        // one liveness snapshot per tick (this runs every monitor poll);
        // the transition helpers keep it in sync with their writes
        let mut states = self.table.liveness_states();
        acted |= self.promote_spawning(&mut states);
        acted |= self.complete_draining(&mut states);
        let (interval, max_ops, min_workers, max_workers, min_total) = {
            let es = self.elastic.as_ref().expect("checked above");
            (
                es.cfg.interval,
                es.cfg.max_ops,
                es.cfg.min_workers,
                es.cfg.max_workers,
                es.min_total,
            )
        };
        {
            let es = self.elastic.as_ref().expect("checked above");
            if es.last_decision.elapsed() < interval || es.ops >= max_ops {
                return acted;
            }
        }
        // measure the window
        let counts = self.state.update_counts();
        let backlog = self.state.published_values();
        let k = self.table.partition().k();
        let deltas: Vec<u64> = {
            let es = self.elastic.as_mut().expect("checked above");
            let deltas = counts
                .iter()
                .zip(&es.last_counts)
                .map(|(now, base)| now.saturating_sub(*base))
                .collect();
            es.last_counts = counts;
            es.last_decision = Instant::now();
            deltas
        };
        self.track_idleness(&states, &deltas, &backlog);
        // a transition in flight (or an unsettled ownership move) blocks
        // new decisions: measurements straddling a migration are noise,
        // and the single-transition-at-a-time rule keeps the state
        // machine trivially serializable
        if states
            .iter()
            .any(|s| matches!(s, PidState::Spawning | PidState::Draining))
            || !self.table.all_acked(self.table.version())
            || self.table.handoffs_inflight() > 0
        {
            return acted;
        }
        let live = self.stats.live;
        // 1. retire: a worker idle past the window hands its Ω away —
        //    this is the merge-side policy from the ROADMAP (regrouping
        //    persistently-idle PIDs frees a core)
        if live > min_workers {
            if let Some((pid, absorber)) = self.pick_retire(&states, &deltas) {
                return self.retire(pid, absorber) || acted;
            }
        }
        // 2. spawn / shed: a persistent straggler sheds half its Ω — to a
        //    brand-new worker while there is headroom, else to the
        //    fastest existing peer (the PR 2 fixed-pool rebalance)
        if total.is_finite() && total > min_total {
            if let Some((straggler, fastest)) = self.pick_straggler(&states, &deltas, &backlog, k)
            {
                if live < max_workers {
                    return self.spawn_split(straggler).is_ok() || acted;
                }
                if let Some(fastest) = fastest {
                    return self.shed(straggler, fastest) || acted;
                }
            }
        }
        acted
    }

    /// Spawning → Live once the worker acked the version that routed
    /// coordinates at it (its handoff may still be flying — that's fine,
    /// Live only means "fully registered and syncing"). Mirrors its
    /// writes into the caller's liveness snapshot.
    fn promote_spawning(&mut self, states: &mut [PidState]) -> bool {
        let v = self.table.version();
        let mut acted = false;
        for pid in 0..states.len() {
            if states[pid] == PidState::Spawning && self.table.acked_version(pid) >= v {
                self.table.set_liveness(pid, PidState::Live);
                states[pid] = PidState::Live;
                acted = true;
            }
        }
        acted
    }

    /// Draining → Retired once the drain version is acked everywhere and
    /// no handoff slice is in flight: deregister the endpoint (stale
    /// senders now fail fast and re-route), then stop and join the
    /// thread — its forwarding exit path drains anything already queued.
    fn complete_draining(&mut self, states: &mut [PidState]) -> bool {
        let v = self.table.version();
        let mut acted = false;
        for pid in 0..states.len() {
            if states[pid] != PidState::Draining {
                continue;
            }
            if !self.table.all_acked(v) || self.table.handoffs_inflight() > 0 {
                continue;
            }
            self.hub.remove_endpoint(pid);
            if let Some(h) = self.slots[pid].take() {
                let _ = h.ctrl.send(Ctrl::Shutdown);
                let _ = h.handle.join();
            }
            self.table.set_liveness(pid, PidState::Retired);
            states[pid] = PidState::Retired;
            // the slot's published share is authoritatively zero now —
            // aggregate and per-query-lane alike (the drain forwarded
            // every lane's fluid before the endpoint came down)
            self.state.publish(pid, 0.0);
            if let Some(qs) = self.cfg.queries.as_ref() {
                qs.zero_published_pid(pid);
            }
            self.stats.retired += 1;
            self.stats.live -= 1;
            self.metrics.incr("pool_retired");
            self.metrics.set("pool_live", self.stats.live as u64);
            acted = true;
        }
        acted
    }

    /// Update per-slot idle clocks: idle = no updates this window AND no
    /// published backlog. A fluid-starved worker is idle, not slow — the
    /// same distinction `plan_rebalance` draws, inverted.
    fn track_idleness(&mut self, states: &[PidState], deltas: &[u64], backlog: &[f64]) {
        let es = self.elastic.as_mut().unwrap();
        let tol = self.cfg.tol;
        for pid in 0..es.idle_since.len() {
            let live = states.get(pid) == Some(&PidState::Live);
            let idle = live && deltas[pid] == 0 && backlog[pid] <= tol;
            if !idle {
                es.idle_since[pid] = None;
            } else if es.idle_since[pid].is_none() {
                es.idle_since[pid] = Some(Instant::now());
            }
        }
    }

    /// The straggler criterion over live, occupied parts (vacant slots
    /// must not drag the median down): lowest per-coordinate rate below
    /// spawn_threshold × median, holding fluid, big enough to split.
    /// Also returns the fastest live peer (the shed target at capacity).
    fn pick_straggler(
        &self,
        states: &[PidState],
        deltas: &[u64],
        backlog: &[f64],
        k: usize,
    ) -> Option<(usize, Option<usize>)> {
        let es = self.elastic.as_ref().unwrap();
        let part = self.table.partition();
        let mut rates: Vec<(usize, f64)> = Vec::new();
        for pid in 0..k {
            if states.get(pid) != Some(&PidState::Live) || part.part(pid).is_empty() {
                continue;
            }
            rates.push((pid, deltas[pid] as f64 / part.part(pid).len() as f64));
        }
        if rates.len() < 2 {
            return None;
        }
        let mut sorted: Vec<f64> = rates.iter().map(|r| r.1).collect();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = sorted[rates.len() / 2];
        if median <= 0.0 {
            return None;
        }
        let &(slowest, slow_rate) = rates
            .iter()
            .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())?;
        // same idle floor as track_idleness: a worker whose residual is
        // below tol is starved/drained, not slow — a window with zero
        // updates and ~1e-14 backlog must not read as a straggler
        if slow_rate >= es.cfg.spawn_threshold * median
            || backlog[slowest] <= self.cfg.tol
            || part.part(slowest).len() < 2 * es.cfg.min_part
        {
            return None;
        }
        let fastest = rates
            .iter()
            .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
            .map(|r| r.0)
            .filter(|&f| f != slowest);
        Some((slowest, fastest))
    }

    /// The retire criterion: the longest-idle live worker past the
    /// policy window, absorbed by the busiest other live worker (its
    /// demonstrated capacity makes it the cheapest place to park an
    /// already-drained Ω).
    fn pick_retire(&self, states: &[PidState], deltas: &[u64]) -> Option<(usize, usize)> {
        let es = self.elastic.as_ref().expect("elastic poll only");
        let now = Instant::now();
        let retiree = (0..es.idle_since.len())
            .filter(|&p| states.get(p) == Some(&PidState::Live))
            .filter_map(|p| {
                es.idle_since[p]
                    .filter(|t| now.duration_since(*t) >= es.cfg.retire_idle)
                    .map(|t| (p, t))
            })
            .min_by_key(|&(_, t)| t)
            .map(|(p, _)| p)?;
        let absorber = (0..states.len())
            .filter(|&p| p != retiree && states[p] == PidState::Live)
            .max_by_key(|&p| deltas.get(p).copied().unwrap_or(0))?;
        Some((retiree, absorber))
    }

    /// Spawn a new live worker and hand it the cut-aware half of
    /// `from`'s Ω. Public so tests (and future policies) can drive the
    /// mechanics directly; the policy path is [`WorkerPool::poll`].
    pub fn spawn_split(&mut self, from: usize) -> Result<usize> {
        let cap = self.state.capacity();
        // prefer reusing a retired slot; else append, bounded by capacity
        let states = self.table.liveness_states();
        let vacant = states.iter().position(|s| *s == PidState::Retired);
        let pid = match vacant {
            Some(p) => p,
            None => {
                let p = self.slots.len();
                if p >= cap {
                    return Err(DiterError::Coordinator(format!(
                        "worker pool at capacity ({cap})"
                    )));
                }
                p
            }
        };
        // 1. the mailbox must exist before any ownership map routes
        //    fluid at the new PID
        let ep = self.hub.add_endpoint(pid)?;
        // 2. widen the table (new slots pre-acked ⇒ quiescence stays
        //    sound while the worker boots) and give the partition a
        //    vacant part for the PID if it does not have one yet
        if pid >= self.table.width() {
            self.table.grow(pid + 1);
        } else {
            self.table.reactivate(pid);
        }
        let part = self.table.partition();
        if pid >= part.k() {
            let grown = part.with_k(pid + 1)?;
            if self.table.install_elastic(grown).is_none() {
                // frozen mid-spawn cannot happen from the poll path (the
                // engine freezes only on its own thread), but fail safe:
                // withdraw the endpoint and report
                self.hub.remove_endpoint(pid);
                self.table.set_liveness(pid, PidState::Retired);
                return Err(DiterError::Coordinator("table frozen during spawn".into()));
            }
        }
        // 3. start the worker: empty Ω, current epoch
        let handle = self.spawn_thread(ep);
        if pid == self.slots.len() {
            self.slots.push(Some(handle));
        } else {
            self.slots[pid] = Some(handle);
        }
        // 4. route the straggler's half at it — the handoff machinery
        //    does the rest
        let part = self.table.partition();
        let coords = choose_shed_half(&part, from, pid, Some(self.problem.matrix()));
        let next = part.transfer_elastic(&coords, pid)?;
        if self.table.install_elastic(next).is_none() {
            return Err(DiterError::Coordinator("table frozen during spawn".into()));
        }
        if let Some(es) = self.elastic.as_mut() {
            es.ops += 1;
        }
        self.stats.spawned += 1;
        self.stats.live += 1;
        self.stats.peak_live = self.stats.peak_live.max(self.stats.live);
        self.metrics.incr("pool_spawned");
        self.metrics.set("pool_live", self.stats.live as u64);
        self.metrics.set("pool_peak_live", self.stats.peak_live as u64);
        Ok(pid)
    }

    /// Begin retiring `pid`: move its whole Ω to `absorber` and mark it
    /// Draining. The retirement completes asynchronously in
    /// [`WorkerPool::poll`] (or [`WorkerPool::settle`]) once the drain
    /// quiesced. Public for tests and direct policy drivers.
    pub fn retire(&mut self, pid: usize, absorber: usize) -> bool {
        if pid == absorber || self.slots.get(pid).map(Option::is_none).unwrap_or(true) {
            return false;
        }
        let part = self.table.partition();
        let coords = part.part(pid).to_vec();
        let Ok(next) = part.transfer_elastic(&coords, absorber) else {
            return false;
        };
        self.table.set_liveness(pid, PidState::Draining);
        if self.table.install_elastic(next).is_none() {
            self.table.set_liveness(pid, PidState::Live);
            return false;
        }
        if let Some(es) = self.elastic.as_mut() {
            es.ops += 1;
            es.idle_since[pid] = None;
        }
        true
    }

    /// Drive pending lifecycle transitions to completion (bounded wait).
    /// Used by tests and by engines that must quiesce the pool outside
    /// the poll loop.
    pub fn settle(&mut self, deadline: Duration) -> bool {
        let until = Instant::now() + deadline;
        loop {
            let mut states = self.table.liveness_states();
            self.promote_spawning(&mut states);
            self.complete_draining(&mut states);
            if !states
                .iter()
                .any(|s| matches!(s, PidState::Spawning | PidState::Draining))
            {
                return true;
            }
            if Instant::now() >= until {
                return false;
            }
            std::thread::sleep(Duration::from_micros(200));
        }
    }

    /// Shed half of `from`'s Ω to `to` on the fixed pool (at capacity).
    fn shed(&mut self, from: usize, to: usize) -> bool {
        let part = self.table.partition();
        let coords = choose_shed_half(&part, from, to, Some(self.problem.matrix()));
        let Ok(next) = part.transfer_elastic(&coords, to) else {
            return false;
        };
        if self.table.install_elastic(next).is_none() {
            return false;
        }
        if let Some(es) = self.elastic.as_mut() {
            es.ops += 1;
        }
        self.stats.sheds += 1;
        self.metrics.set("handoffs_planned", self.stats.sheds);
        true
    }

    // ------------------------------------------------------------------
    // crash tolerance (DESIGN.md §11)

    /// Failure detection + checkpoint ticking + recovery, run on every
    /// poll tick before the elastic gate (fixed pools are crash-tolerant
    /// too). Allocation-free until a knob is on or a death is detected,
    /// so the no-failure hot path is unchanged. Returns true when a
    /// recovery completed — engines must reset their stability window,
    /// the reconstructed fluid re-converges from checkpoint H.
    fn poll_crashes(&mut self) -> bool {
        // a stopping pool legitimately has finished threads in occupied
        // slots — never read shutdown as a crash
        if self.state.should_stop() {
            return false;
        }
        let mut acted = self.tick_checkpoint();
        if let Some(hb) = self.cfg.heartbeat {
            // in-process, a wedged-but-alive thread cannot be killed,
            // only observed: surface staleness as a gauge and let
            // max_wall bound the run (remote mode escalates the same
            // staleness to WorkerDied — it CAN abandon a process)
            let limit = hb.as_millis() as u64;
            for pid in 0..self.slots.len() {
                if self.slots[pid].is_some()
                    && self.state.staleness_ms(pid).is_some_and(|ms| ms > limit)
                {
                    self.metrics.incr("worker_stale_beats");
                }
            }
        }
        for pid in 0..self.slots.len() {
            let finished = self.slots[pid]
                .as_ref()
                .is_some_and(|h| h.handle.is_finished());
            if !finished || self.table.liveness(pid) == PidState::Draining {
                // Draining threads exit through their own Shutdown —
                // complete_draining joins those
                continue;
            }
            // death detected: the per-pid bookkeeping happens exactly
            // once, here; recovery below retries across ticks if blocked
            self.table.set_liveness(pid, PidState::Dead);
            self.state.invalidate(pid);
            if let Some(h) = self.slots[pid].take() {
                let _ = h.handle.join(); // finished ⇒ immediate; Err IS the crash
            }
            self.hub.remove_endpoint(pid);
            self.stats.crashes += 1;
            self.metrics.incr("pool_crashes");
            self.dead_pending.push(pid);
        }
        if !self.dead_pending.is_empty() {
            acted |= self.recover();
        }
        acted
    }

    /// Non-blocking incremental checkpointing: at most one outstanding
    /// journal request, one worker per interval in round robin. The
    /// worker replies between steps; the reply is folded in on a LATER
    /// tick — the monitor thread never waits on a worker, and no global
    /// barrier is ever taken for a checkpoint.
    fn tick_checkpoint(&mut self) -> bool {
        let Some(every) = self.cfg.checkpoint_every else {
            return false;
        };
        if let Some((pid, rx)) = self.ckpt_pending.take() {
            match rx.try_recv() {
                Ok((_, epoch, full, coords, h)) => {
                    self.merge_journal(pid, epoch, full, coords, h);
                    self.metrics.incr("pool_checkpoints");
                    return true;
                }
                Err(TryRecvError::Empty) => {
                    self.ckpt_pending = Some((pid, rx));
                    return false;
                }
                // the worker died mid-journal: detection owns the slot
                Err(TryRecvError::Disconnected) => return false,
            }
        }
        if self.last_checkpoint.elapsed() < every {
            return false;
        }
        let k = self.slots.len();
        for off in 0..k {
            let pid = (self.ckpt_rr + off) % k;
            if self.table.liveness(pid) != PidState::Live {
                continue;
            }
            let Some(slot) = self.slots[pid].as_ref() else {
                continue;
            };
            let (tx, rx) = channel();
            if slot.ctrl.send(Ctrl::Journal { reply: tx }).is_ok() {
                self.ckpt_pending = Some((pid, rx));
                self.ckpt_rr = pid + 1;
                break;
            }
        }
        self.last_checkpoint = Instant::now();
        false
    }

    /// Fold one journal reply into the per-pid checkpoint store. A full
    /// snapshot re-seats the basis; a delta patches rows of the SAME
    /// basis epoch. The worker full-snapshots on any owned-set or epoch
    /// change, so a mismatched delta means the basis is gone — drop it
    /// and wait for the next full.
    fn merge_journal(
        &mut self,
        pid: usize,
        epoch: u64,
        full: bool,
        coords: Vec<usize>,
        h: Vec<f64>,
    ) {
        let lanes = self.cfg.lanes.max(1);
        if self.checkpoints.len() <= pid {
            self.checkpoints.resize_with(pid + 1, || None);
        }
        if full {
            let pos = coords.iter().enumerate().map(|(r, &i)| (i, r)).collect();
            self.checkpoints[pid] = Some(Checkpoint { epoch, pos, h });
            return;
        }
        let Some(ck) = self.checkpoints[pid].as_mut() else {
            return;
        };
        if ck.epoch != epoch {
            return;
        }
        for (r, &i) in coords.iter().enumerate() {
            if let Some(&row) = ck.pos.get(&i) {
                ck.h[row * lanes..(row + 1) * lanes]
                    .copy_from_slice(&h[r * lanes..(r + 1) * lanes]);
            }
        }
    }

    /// The recovery sequence. Exactness rests on the F-invariant
    /// (DESIGN.md §11): `F = B + (P − I)·H` holds for ANY H, so fluid
    /// lost with a dead worker is *recomputed*, not replayed — from the
    /// best-known global H (survivor barrier replies + the dead pids'
    /// stored checkpoints, zero where nothing is known). An epoch bump
    /// fences the crash: every parcel and handoff still in flight from
    /// before it is discarded-and-committed by its receiver, so nothing
    /// stale can double-apply. Progress since the last checkpoint is
    /// lost; the fixed point is not.
    fn recover(&mut self) -> bool {
        // 1. quiesce the survivors onto one consistent owner map: every
        // live pid acked the current version (Dead slots are exempt) and
        // no handoff slice is booked. An in-progress fold settles in
        // milliseconds; a slice stranded by the death would hold
        // `handoffs_inflight` high forever — force-clear it after a
        // grace period and re-wait. The fluid it carried is NOT lost:
        // step 5 recomputes all fluid from H.
        let mut deadline = Instant::now() + Duration::from_secs(2);
        let mut cleared = false;
        loop {
            if self.table.all_acked(self.table.version()) && self.table.handoffs_inflight() == 0
            {
                break;
            }
            if Instant::now() >= deadline {
                if !cleared && self.table.handoffs_inflight() > 0 {
                    self.table.clear_handoffs();
                    cleared = true;
                    deadline = Instant::now() + Duration::from_secs(2);
                    continue;
                }
                return false; // a survivor is wedged; retry next tick
            }
            std::thread::sleep(Duration::from_micros(200));
        }
        // 2. barrier-checkpoint the survivors: recovery needs a global
        // H, and each reply pins its worker's exact owned set while the
        // worker pauses for the resume
        let Ok(live) = self.checkpoint() else {
            return false; // another death mid-barrier; retry next tick
        };
        let dead = self.dead_pending.clone();
        // 3. transport reconciliation: survivors sever connections to
        // the dead pids and release retention charged at them (wire) —
        // while paused, before the slots re-register
        for slot in self.slots.iter().flatten() {
            let (tx, rx) = channel();
            let mut expect = 0usize;
            for &pid in &dead {
                if slot
                    .ctrl
                    .send(Ctrl::Reconcile {
                        pid,
                        reply: tx.clone(),
                    })
                    .is_ok()
                {
                    expect += 1;
                }
            }
            drop(tx);
            for _ in 0..expect {
                let _ = rx.recv_timeout(Duration::from_secs(5));
            }
        }
        // 4. orphaned coordinates: a handoff slice that died with the
        // crash can leave coords no survivor holds while the owner map
        // still routes their fluid at one (which would foster it
        // forever). Fold every coordinate covered by neither a survivor
        // reply nor a dead part into the first dead slot — the respawn
        // below owns them from checkpoint (or zero) H.
        let n = self.problem.n();
        let mut covered = vec![false; n];
        for (_, coords, _) in &live {
            for &i in coords {
                covered[i] = true;
            }
        }
        {
            let part = self.table.partition();
            for &pid in &dead {
                for &i in part.part(pid) {
                    covered[i] = true;
                }
            }
            let orphans: Vec<usize> = (0..n).filter(|&i| !covered[i]).collect();
            if !orphans.is_empty() {
                if let Ok(next) = part.transfer_elastic(&orphans, dead[0]) {
                    // cannot be frozen here: recovery runs on the same
                    // thread that freezes (the engine's monitor loop)
                    let _ = self.table.install_elastic(next);
                }
            }
        }
        let part = self.table.partition();
        // 5. assemble the best-known global H, one dense vector per lane
        let lanes = self.cfg.lanes.max(1);
        let mut hs = vec![vec![0.0; n]; lanes];
        for (_, coords, slice) in &live {
            for (t, &i) in coords.iter().enumerate() {
                for (l, h) in hs.iter_mut().enumerate() {
                    h[i] = slice[t * lanes + l];
                }
            }
        }
        for &pid in &dead {
            let Some(ck) = self.checkpoints.get(pid).and_then(Option::as_ref) else {
                continue; // no checkpoint yet: cold H = 0 over its part
            };
            for &i in part.part(pid) {
                if let Some(&row) = ck.pos.get(&i) {
                    for (l, h) in hs.iter_mut().enumerate() {
                        h[i] = ck.h[row * lanes + l];
                    }
                }
            }
        }
        // 6. per-lane B: lane 0 is the problem's own B; query lanes
        // re-claim every pending seed (mirrors rebase_gather — the
        // recomputed F injects them, so seeds claimed by the dead
        // worker revive instead of leaking)
        let qs = self.cfg.queries.clone();
        let lane_b: Vec<Vec<f64>> = (0..lanes)
            .map(|l| {
                if l == 0 {
                    self.problem.b().to_vec()
                } else {
                    qs.as_ref()
                        .and_then(|q| q.lane_b_claim_all(l, n))
                        .unwrap_or_else(|| vec![0.0; n])
                }
            })
            .collect();
        // 7. the epoch fence + exact reconstruction of every slice
        let new_epoch = self.epoch + 1;
        let problem = self.problem.clone();
        let state = self.state.clone();
        let reconstruct = |kk: usize, coords: &[usize]| -> Vec<f64> {
            let mut f_slice = vec![0.0; coords.len() * lanes];
            let mut aggregate = 0.0;
            for (l, hl) in hs.iter().enumerate() {
                let f_l = update::reconstruct_f_slice(problem.matrix(), coords, hl, &lane_b[l]);
                let mass: f64 = f_l.iter().map(|v| v.abs()).sum();
                aggregate += mass;
                if l >= 1 {
                    if let Some(q) = qs.as_ref() {
                        q.publish_lane(kk, l, mass);
                    }
                }
                for (t, v) in f_l.into_iter().enumerate() {
                    f_slice[t * lanes + l] = v;
                }
            }
            // pre-publish so the monitor errs high until the worker's
            // own publish lands (same discipline as rebase_gather)
            state.publish(kk, aggregate);
            f_slice
        };
        let mut live_slices = Vec::with_capacity(live.len());
        for (kk, coords, _) in &live {
            live_slices.push((*kk, reconstruct(*kk, coords)));
        }
        // 8. respawn each dead slot warm — restored H, reconstructed F,
        // the new epoch — and set it Live directly (it acks on build;
        // fixed pools never run promote_spawning)
        for &pid in &dead {
            let coords: Vec<usize> = part.part(pid).to_vec();
            if let Some(q) = qs.as_ref() {
                // the dead worker's per-lane published shares are stale
                q.zero_published_pid(pid);
            }
            let f_slice = reconstruct(pid, &coords);
            let mut h_slice = vec![0.0; coords.len() * lanes];
            for (t, &i) in coords.iter().enumerate() {
                for (l, hl) in hs.iter().enumerate() {
                    h_slice[t * lanes + l] = hl[i];
                }
            }
            self.table.reactivate(pid);
            let Ok(ep) = self.hub.add_endpoint(pid) else {
                // endpoint slot unusable (should not happen — detection
                // freed it): leave the pid Dead, bounded by max_wall
                self.table.set_liveness(pid, PidState::Dead);
                continue;
            };
            let mut core = WorkerCore::new(
                pid,
                ep,
                problem.clone(),
                self.table.clone(),
                self.state.clone(),
                self.cfg.clone(),
            );
            core.restore_history(&h_slice);
            core.enter_epoch(new_epoch, problem.clone(), f_slice, Some(&[]));
            let handle = self.spawn_core(core);
            self.slots[pid] = Some(handle);
            self.table.set_liveness(pid, PidState::Live);
            self.stats.recoveries += 1;
            self.metrics.incr("pool_recoveries");
        }
        // 9. release the paused survivors into the new epoch
        let _ = self.resume(new_epoch, problem, live_slices, Some(Arc::new(Vec::new())));
        self.dead_pending.clear();
        true
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        // dropping the control senders terminates the worker loops; the
        // threads unwind on their own (finish() joins them explicitly)
        for slot in self.slots.iter().flatten() {
            let _ = slot.ctrl.send(Ctrl::Shutdown);
        }
    }
}

/// One persistent PID worker: the shared core plus pool control. Exits
/// on `Ctrl::Shutdown`, a disconnected control channel, or the monitor's
/// stop flag (the one-shot engines stop the whole pool at once).
struct PoolWorker {
    core: WorkerCore,
    ctrl: Receiver<Ctrl>,
    state: Arc<MonitorState>,
    /// (target epoch, ack channel) of an in-flight local rebase — sent
    /// once the core's halo state machine has entered the epoch
    rebase_ack: Option<(u64, Sender<usize>)>,
    /// set by [`Ctrl::Die`]: exit like a crash, skipping the drain
    killed: bool,
}

impl PoolWorker {
    fn run(mut self) -> (Vec<usize>, Vec<f64>) {
        loop {
            if self.state.should_stop() {
                break;
            }
            // liveness stamp: one relaxed store per iteration — the
            // monitor reads staleness, no heartbeat message is sent
            self.state.beat(self.core.pid());
            self.maybe_ack_rebase();
            match self.ctrl.try_recv() {
                Ok(c) => {
                    if !self.handle_ctrl(c) {
                        break;
                    }
                    continue; // drain further control messages first
                }
                Err(TryRecvError::Empty) => {}
                Err(TryRecvError::Disconnected) => break,
            }
            let (got_fluid, r_k) = self.core.step();
            if !got_fluid && r_k == 0.0 && self.core.is_drained() {
                std::thread::sleep(Duration::from_micros(50));
            }
        }
        if self.killed {
            // simulated crash: exit WITHOUT the forwarding drain — the
            // endpoint drops with parcels still queued and retention
            // unacked, exactly like a panicking thread. The transport's
            // drop reconciliation and the pool's recovery settle the
            // books; the return value is never read (the slot is taken
            // by detection, not by finish()).
            return (Vec::new(), Vec::new());
        }
        self.core.finish()
    }

    /// Ack a completed local epoch entry back to the coordinator. The
    /// entry happens inside `step` (when the last awaited halo arrives)
    /// or inside `handle_ctrl` (nothing awaited), so the check runs every
    /// loop iteration.
    fn maybe_ack_rebase(&mut self) {
        let entered =
            matches!(&self.rebase_ack, Some((target, _)) if self.core.epoch() >= *target);
        if !entered {
            return;
        }
        if let Some((_, tx)) = self.rebase_ack.take() {
            let _ = tx.send(self.core.pid());
        }
    }

    fn reply_state(&self, reply: &Sender<(usize, Vec<usize>, Vec<f64>)>) {
        let _ = reply.send((
            self.core.pid(),
            self.core.owned().to_vec(),
            self.core.h().to_vec(),
        ));
    }

    fn reply_journal(&mut self, reply: &Sender<(usize, u64, bool, Vec<usize>, Vec<f64>)>) {
        let (epoch, full, coords, h) = self.core.journal();
        let _ = reply.send((self.core.pid(), epoch, full, coords, h));
    }

    /// Returns false when the worker must terminate.
    fn handle_ctrl(&mut self, c: Ctrl) -> bool {
        match c {
            Ctrl::Snapshot { reply } => {
                self.reply_state(&reply);
                true
            }
            Ctrl::Shutdown => false,
            Ctrl::Checkpoint { reply } => {
                self.reply_state(&reply);
                // paused: block until the coordinator resumes us
                loop {
                    match self.ctrl.recv() {
                        Ok(Ctrl::Resume {
                            epoch,
                            problem,
                            f_slice,
                            dirty,
                        }) => {
                            self.core.enter_epoch(
                                epoch,
                                problem,
                                f_slice,
                                dirty.as_ref().map(|d| d.as_slice()),
                            );
                            return true;
                        }
                        Ok(Ctrl::Snapshot { reply }) | Ok(Ctrl::Checkpoint { reply }) => {
                            self.reply_state(&reply);
                        }
                        Ok(Ctrl::Journal { reply }) => {
                            self.reply_journal(&reply);
                        }
                        Ok(Ctrl::Reconcile { pid, reply }) => {
                            // recovery reconciles survivors while they
                            // pause at exactly this barrier
                            self.core.reconcile_peer(pid);
                            let _ = reply.send(self.core.pid());
                        }
                        Ok(Ctrl::RebaseLocal { .. }) => {
                            // the two protocols never mix within a run: a
                            // checkpoint pause (gather) cannot receive a
                            // local transition
                            debug_assert!(false, "RebaseLocal during a checkpoint pause");
                        }
                        Ok(Ctrl::Die) => {
                            self.killed = true;
                            return false;
                        }
                        Ok(Ctrl::Shutdown) | Err(_) => return false,
                    }
                }
            }
            Ctrl::RebaseLocal {
                epoch,
                problem,
                dirty,
                reply,
            } => {
                self.core.begin_rebase_local(epoch, problem, dirty);
                // acked from the run loop once the halo exchange settles
                self.rebase_ack = Some((epoch, reply));
                true
            }
            Ctrl::Journal { reply } => {
                self.reply_journal(&reply);
                true
            }
            Ctrl::Reconcile { pid, reply } => {
                self.core.reconcile_peer(pid);
                let _ = reply.send(self.core.pid());
                true
            }
            Ctrl::Die => {
                self.killed = true;
                false
            }
            Ctrl::Resume {
                epoch,
                problem,
                f_slice,
                dirty,
            } => {
                // resume without a checkpoint (defensive: coordinator
                // always checkpoints first, but the transition is safe
                // from any state)
                self.core.enter_epoch(
                    epoch,
                    problem,
                    f_slice,
                    dirty.as_ref().map(|d| d.as_slice()),
                );
                true
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{pagerank_system, power_law_web_graph};
    use crate::linalg::vec_ops::norm1;
    use crate::partition::Partition;

    fn pagerank_problem(n: usize, seed: u64) -> Arc<FixedPointProblem> {
        let g = power_law_web_graph(n, 5, 0.1, seed);
        let sys = pagerank_system(&g, 0.85, true).unwrap();
        Arc::new(FixedPointProblem::new(sys.matrix.clone(), sys.b.clone()).unwrap())
    }

    fn gather(pairs: &[(usize, Vec<usize>, Vec<f64>)], n: usize) -> Vec<f64> {
        let mut x = vec![0.0; n];
        for (_, coords, vals) in pairs {
            for (t, &i) in coords.iter().enumerate() {
                x[i] = vals[t];
            }
        }
        x
    }

    #[test]
    fn pool_spawn_and_retire_lifecycle() {
        let n = 60;
        let problem = pagerank_problem(n, 3);
        let cfg = DistributedConfig::new(Partition::contiguous(n, 2).unwrap())
            .with_tol(1e-10)
            .with_seed(3)
            .with_elastic(ElasticConfig {
                max_workers: 4,
                ..Default::default()
            });
        let mut pool = WorkerPool::new(problem, cfg).unwrap();
        assert_eq!(pool.live_pids(), vec![0, 1]);
        // live split: a third worker absorbs half of PID 0's Ω
        let pid = pool.spawn_split(0).unwrap();
        assert_eq!(pid, 2);
        assert!(pool.settle(Duration::from_secs(5)), "spawn must settle");
        assert_eq!(pool.table.liveness(2), PidState::Live);
        assert_eq!(pool.stats().spawned, 1);
        assert_eq!(pool.stats().live, 3);
        let part = pool.table.partition();
        assert_eq!(part.k(), 3);
        assert!(!part.part(2).is_empty(), "the spawn took real ownership");
        // live merge: retire it again, absorbed by PID 1
        assert!(pool.retire(2, 1));
        assert!(pool.settle(Duration::from_secs(5)), "retire must settle");
        assert_eq!(pool.table.liveness(2), PidState::Retired);
        assert_eq!(pool.stats().retired, 1);
        assert_eq!(pool.stats().live, 2);
        assert!(pool.table.partition().part(2).is_empty());
        // respawn reuses the vacant slot
        let pid = pool.spawn_split(1).unwrap();
        assert_eq!(pid, 2, "retired slot is recycled");
        assert!(pool.settle(Duration::from_secs(5)));
        assert_eq!(pool.stats().live, 3);
        // the exact cover survived the whole dance, and so did the fluid:
        // let the diffusion run out, then the gathered solution is the
        // fixed point with unit mass
        let state = pool.state().clone();
        let mon = pool.monitor();
        let deadline = Instant::now() + Duration::from_secs(30);
        loop {
            let total = state.published_total() + mon.inflight_or_zero();
            if (total < 1e-10 && mon.undelivered() == 0) || Instant::now() >= deadline {
                break;
            }
            std::thread::sleep(Duration::from_micros(300));
        }
        state.request_stop();
        let pairs = pool.finish().unwrap();
        let mut x = vec![0.0; n];
        let mut covered = 0;
        for (owned, vals) in &pairs {
            for (t, &i) in owned.iter().enumerate() {
                x[i] = vals[t];
                covered += 1;
            }
        }
        assert_eq!(covered, n, "exact cover after spawn/retire/respawn");
        assert!(
            (norm1(&x) - 1.0).abs() < 1e-7,
            "PageRank mass conserved: ‖x‖₁ = {}",
            norm1(&x)
        );
    }

    #[test]
    fn pool_kill_and_recover_reaches_exact_fixed_point() {
        let n = 60;
        let problem = pagerank_problem(n, 7);
        let cfg = DistributedConfig::new(Partition::contiguous(n, 3).unwrap())
            .with_tol(1e-10)
            .with_seed(7)
            .with_checkpoint_every(Duration::from_millis(2))
            .with_heartbeat(Duration::from_millis(500));
        let mut pool = WorkerPool::new(problem, cfg).unwrap();
        // let real progress accrue and a few incremental checkpoints land
        let warm = Instant::now() + Duration::from_millis(40);
        while Instant::now() < warm {
            pool.poll(f64::INFINITY);
            std::thread::sleep(Duration::from_micros(500));
        }
        // crash a worker mid-diffusion: no drain, no goodbye
        assert!(pool.kill(1));
        let deadline = Instant::now() + Duration::from_secs(20);
        while pool.stats().recoveries == 0 && Instant::now() < deadline {
            pool.poll(f64::INFINITY);
            std::thread::sleep(Duration::from_millis(1));
        }
        assert_eq!(pool.stats().crashes, 1, "the kill must be detected");
        assert_eq!(pool.stats().recoveries, 1, "the slot must be respawned");
        assert_eq!(pool.table.liveness(1), PidState::Live);
        assert!(pool.epoch() >= 1, "recovery fences with an epoch bump");
        // after recovery the run must converge to the exact fixed point —
        // conservation holds through the crash because all fluid was
        // recomputed from H, never replayed
        let state = pool.state().clone();
        let mon = pool.monitor();
        let deadline = Instant::now() + Duration::from_secs(30);
        loop {
            let total = state.published_total() + mon.inflight_or_zero();
            if (total < 1e-10 && mon.undelivered() == 0) || Instant::now() >= deadline {
                break;
            }
            pool.poll(total);
            std::thread::sleep(Duration::from_micros(300));
        }
        state.request_stop();
        let pairs = pool.finish().unwrap();
        let mut x = vec![0.0; n];
        let mut covered = 0;
        for (owned, vals) in &pairs {
            for (t, &i) in owned.iter().enumerate() {
                x[i] = vals[t];
                covered += 1;
            }
        }
        assert_eq!(covered, n, "exact cover after crash + recovery");
        assert!(
            (norm1(&x) - 1.0).abs() < 1e-7,
            "PageRank mass conserved through the crash: ‖x‖₁ = {}",
            norm1(&x)
        );
    }

    #[test]
    fn snapshot_covers_all_live_workers() {
        let n = 40;
        let problem = pagerank_problem(n, 9);
        let cfg = DistributedConfig::new(Partition::contiguous(n, 3).unwrap())
            .with_tol(1e-9)
            .with_seed(9);
        let pool = WorkerPool::new(problem, cfg).unwrap();
        let pairs = pool.snapshot().unwrap();
        assert_eq!(pairs.len(), 3);
        let covered: usize = pairs.iter().map(|(_, c, _)| c.len()).sum();
        assert_eq!(covered, n);
        let _ = gather(&pairs, n);
        pool.state().request_stop();
        pool.finish().unwrap();
    }
}
