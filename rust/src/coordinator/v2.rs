//! V2 distributed scheme (§3.3): partial state + fluid transmission.
//!
//! Every `PID_k` keeps **only** its local slice `(B, H, F)_{i∈Ω_k}`.
//! Diffusing node `i ∈ Ω_k` with fluid `f = F_i` walks the *column*
//! `C_i(P)`: contributions `p_{ji}·f` to locally-owned j are applied
//! immediately; contributions to remote j are **coalesced** per destination
//! PID ("we can regroup (f₁+…+f_m)·p_{ji}; we don't need to know who sent
//! the fluid") and shipped over the bus, which retains every parcel until
//! acknowledged — fluid is never lost.
//!
//! Convergence is monitored *exactly*: every unit of fluid is either in a
//! PID's local F (published), held in a coalescing buffer (published by
//! its owner), or in flight (tracked by the bus) — the total is the
//! paper's "locally updated F_n plus all fluids being transmitted".

use std::sync::Arc;
use std::time::Duration;

use super::monitor::{run_monitor, MonitorState};
use super::{DistributedConfig, DistributedSolution};
use crate::error::{DiterError, Result};
use crate::linalg::vec_ops::norm1;
use crate::metrics::ConvergenceTrace;
use crate::solver::{FixedPointProblem, SequenceKind, SequenceState};
use crate::transport::{bus, monitor_of, BusConfig, CoalesceBuffer, Endpoint};

/// V2 message: a batch of (global coordinate, fluid) parcels.
#[derive(Clone, Debug)]
pub struct FluidMsg {
    pub parcels: Vec<(usize, f64)>,
}

/// Solve with the V2 scheme.
pub fn solve_v2(
    problem: &FixedPointProblem,
    cfg: &DistributedConfig,
) -> Result<DistributedSolution> {
    let n = problem.n();
    if cfg.partition.n() != n {
        return Err(DiterError::shape("solve_v2 partition", n, cfg.partition.n()));
    }
    let k = cfg.partition.k();
    let state = MonitorState::new(k);
    let (endpoints, bus_metrics) = bus::<FluidMsg>(
        k,
        &BusConfig {
            latency: cfg.latency,
            seed: cfg.seed,
        },
    );
    let bus_mon = monitor_of(&endpoints[0]);
    let problem = Arc::new(problem.clone());
    let partition = Arc::new(cfg.partition.clone());

    let mut handles = Vec::with_capacity(k);
    for (kk, ep) in endpoints.into_iter().enumerate() {
        let problem = problem.clone();
        let partition = partition.clone();
        let state = state.clone();
        let cfg = cfg.clone();
        handles.push(std::thread::spawn(move || {
            v2_worker(kk, ep, &problem, &partition, &state, &cfg)
        }));
    }

    let (converged_mon, trace, wall) = run_monitor(
        &state,
        &bus_mon,
        n,
        cfg.tol,
        cfg.max_wall,
        Duration::from_micros(200),
        3,
    );

    let mut x = vec![0.0; n];
    for h in handles {
        let (owned, values) = h
            .join()
            .map_err(|_| DiterError::Coordinator("V2 worker panicked".into()))?;
        for (t, &i) in owned.iter().enumerate() {
            x[i] = values[t];
        }
    }
    let residual = problem.residual_norm(&x);
    Ok(DistributedSolution {
        residual,
        converged: converged_mon && residual <= cfg.tol * 10.0,
        cost: state.max_updates() as f64 / n as f64,
        total_updates: state.total_updates(),
        wall_secs: wall,
        trace: relabel(trace, "v2-total-fluid"),
        metrics: bus_metrics.snapshot(),
        x,
    })
}

fn relabel(mut t: ConvergenceTrace, name: &str) -> ConvergenceTrace {
    t.name = name.to_string();
    t
}

/// One PID's work loop. Local state is strictly the owned slice.
fn v2_worker(
    k: usize,
    mut ep: Endpoint<FluidMsg>,
    problem: &FixedPointProblem,
    partition: &crate::partition::Partition,
    state: &MonitorState,
    cfg: &DistributedConfig,
) -> (Vec<usize>, Vec<f64>) {
    let csc = problem.matrix().csc();
    let owned: Vec<usize> = partition.part(k).to_vec();
    let m = owned.len();
    // global index → local position (only valid for owned coordinates)
    let mut local_of = vec![usize::MAX; problem.n()];
    for (t, &i) in owned.iter().enumerate() {
        local_of[i] = t;
    }
    // F₀ = B on the owned slice, H₀ = 0 (eq. 2/3 initial condition)
    let mut f_loc: Vec<f64> = owned.iter().map(|&i| problem.b()[i]).collect();
    let mut h_loc: Vec<f64> = vec![0.0; m];
    let mut coalesce = CoalesceBuffer::new(partition.k(), cfg.coalesce);
    // sequence over local positions 0..m. Greedy uses the exponent-bucket
    // queue: an O(m) scan per pick makes a pass O(m²), and a per-increment
    // snapshot heap explodes on hub columns (§Perf iterations 1-3).
    let use_heap = cfg.sequence == SequenceKind::GreedyMaxFluid;
    let mut heap = crate::solver::GreedyQueue::new(m);
    if use_heap {
        for (t, &fv) in f_loc.iter().enumerate() {
            heap.push(t, fv.abs());
        }
    }
    let mut seq = SequenceState::new(
        cfg.sequence,
        (0..m).collect(),
        cfg.seed ^ (k as u64).wrapping_mul(0x9E3779B97F4A7C15),
    );
    let mut threshold = cfg.threshold0;
    let quanta = cfg.sweeps_per_round * m;
    // absorb-without-propagation floor: fluid below tol/(10·N) is folded
    // into H but not re-emitted. Total extra residual ≤ N·floor = tol/10,
    // well inside the target — and it terminates the asymptotic ping-pong
    // tail that otherwise circulates ever-smaller parcels down to the
    // float-zero limit (§Perf iteration 4: the 50k e2e spent most of its
    // wall time pushing sub-1e-12 crumbs around).
    let absorb_eps = (cfg.tol / (10.0 * problem.n() as f64)).max(1e-300);

    loop {
        if state.should_stop() {
            break;
        }
        // 1. absorb incoming fluid. Two-phase: apply, publish the new
        //    local total, THEN commit — so at every instant the monitor
        //    sees each unit of fluid in at least one account.
        let received = ep.drain_uncommitted();
        let got_fluid = !received.is_empty();
        for msg in &received {
            for &(j, fl) in &msg.payload.parcels {
                let t = local_of[j];
                f_loc[t] += fl;
                if use_heap {
                    heap.push(t, f_loc[t].abs());
                }
            }
        }
        if got_fluid {
            state.publish(k, norm1(&f_loc) + coalesce.held_mass());
            for msg in &received {
                ep.commit(msg.from, msg.seq, msg.mass);
            }
        }
        ep.collect_acks();
        // 2. diffusion quantum over owned coordinates
        let mut did_work = false;
        let mut work_count = 0u64;
        for _ in 0..quanta {
            let t = if use_heap {
                match heap.pop_valid(|t| f_loc[t]) {
                    Some(t) => t,
                    None => break, // locally drained
                }
            } else {
                seq.next(&f_loc)
            };
            let fi = f_loc[t];
            if fi == 0.0 {
                continue;
            }
            if fi.abs() < absorb_eps {
                h_loc[t] += fi;
                f_loc[t] = 0.0;
                continue;
            }
            did_work = true;
            work_count += 1;
            h_loc[t] += fi;
            f_loc[t] = 0.0;
            let (rows, vals) = csc.col(owned[t]);
            for u in 0..rows.len() {
                let j = rows[u];
                let contrib = vals[u] * fi;
                let lj = local_of[j];
                if lj != usize::MAX {
                    f_loc[lj] += contrib; // stays local
                    if use_heap {
                        heap.push(lj, f_loc[lj].abs());
                    }
                } else {
                    coalesce.add(partition.owner(j), j, contrib); // §3.3 regroup
                }
            }
        }
        // only actual diffusions count as work: idle spinning while the
        // monitor confirms quiescence must not inflate the cost metric
        state.add_updates(k, work_count);
        // 3. ship coalesced parcels: policy-ready destinations always;
        //    everything when the threshold trips (§4.3: F sent when
        //    r_k < T_k) or when the local fluid is fully diffused (so no
        //    sub-`min_mass` remnant can strand — guarantees drainage).
        let r_k = norm1(&f_loc);
        let threshold_hit = did_work && r_k < threshold;
        if threshold_hit || r_k < cfg.tol {
            // locally (near-)drained: hold nothing back, whatever its size
            for (dest, batch, mass) in coalesce.take_all() {
                send_batch(&mut ep, dest, batch, mass);
            }
        } else {
            for dest in coalesce.ready() {
                let (batch, mass) = coalesce.take(dest);
                send_batch(&mut ep, dest, batch, mass);
            }
        }
        if threshold_hit && threshold > cfg.tol * 1e-3 {
            // §4.1: T_k ← T_k/α — only after a quantum that did work, and
            // floored near the global tolerance (dividing into denormals
            // serves nothing once r_k itself is far below target).
            threshold /= cfg.threshold_alpha;
        }
        // 4. publish local remaining fluid: F + held-back coalesced mass
        state.publish(k, norm1(&f_loc) + coalesce.held_mass());
        // 5. idle backoff when fully drained
        if !got_fluid && r_k == 0.0 && coalesce.is_empty() {
            std::thread::sleep(Duration::from_micros(50));
        }
    }
    // final drain so no fluid is stranded in our inbox accounting
    ep.collect_acks();
    if std::env::var_os("DITER_DEBUG").is_some() {
        let nonzero = f_loc.iter().filter(|v| **v != 0.0).count();
        eprintln!(
            "[v2 pid {k}] exit: r_k={:.3e} held={:.3e} threshold={:.3e} unacked={} heap={} nonzero_f={}",
            norm1(&f_loc),
            coalesce.held_mass(),
            threshold,
            ep.unacked(),
            heap.len(),
            nonzero
        );
    }
    (owned, h_loc)
}

fn send_batch(ep: &mut Endpoint<FluidMsg>, dest: usize, batch: Vec<(usize, f64)>, mass: f64) {
    if batch.is_empty() {
        return;
    }
    let bytes = batch.len() * 16 + 16;
    let _ = ep.send(dest, FluidMsg { parcels: batch }, mass, bytes);
}

/// Sequence kinds that make sense for V2 (greedy reads local fluid, which
/// is exactly the information V2 keeps — the paper's recommended pairing).
pub fn default_v2_sequence() -> SequenceKind {
    SequenceKind::GreedyMaxFluid
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{pagerank_system, paper_matrix, power_law_web_graph};
    use crate::linalg::vec_ops::{dist_inf, norm1 as vnorm1};
    use crate::partition::Partition;

    fn problem(which: u8) -> FixedPointProblem {
        FixedPointProblem::from_linear_system(&paper_matrix(which), &[1.0; 4]).unwrap()
    }

    #[test]
    fn two_pids_solve_a1() {
        let p = problem(1);
        let cfg = DistributedConfig::new(Partition::contiguous(4, 2).unwrap()).with_tol(1e-12);
        let sol = solve_v2(&p, &cfg).unwrap();
        assert!(sol.converged, "residual {}", sol.residual);
        assert!(dist_inf(&sol.x, &p.exact_solution().unwrap()) < 1e-9);
    }

    #[test]
    fn coupled_matrices_converge() {
        for which in 2..=3u8 {
            let p = problem(which);
            let cfg =
                DistributedConfig::new(Partition::contiguous(4, 2).unwrap()).with_tol(1e-12);
            let sol = solve_v2(&p, &cfg).unwrap();
            assert!(sol.converged, "A({which}) residual {}", sol.residual);
            assert!(dist_inf(&sol.x, &p.exact_solution().unwrap()) < 1e-9);
        }
    }

    #[test]
    fn greedy_sequence_v2() {
        let p = problem(2);
        let cfg = DistributedConfig::new(Partition::contiguous(4, 2).unwrap())
            .with_tol(1e-12)
            .with_sequence(SequenceKind::GreedyMaxFluid);
        let sol = solve_v2(&p, &cfg).unwrap();
        assert!(sol.converged);
        assert!(dist_inf(&sol.x, &p.exact_solution().unwrap()) < 1e-9);
    }

    #[test]
    fn pagerank_web_graph_4_pids() {
        let g = power_law_web_graph(400, 5, 0.1, 11);
        let sys = pagerank_system(&g, 0.85, true).unwrap();
        let p = FixedPointProblem::new(sys.matrix.clone(), sys.b.clone()).unwrap();
        let cfg =
            DistributedConfig::new(Partition::contiguous(400, 4).unwrap()).with_tol(1e-10);
        let sol = solve_v2(&p, &cfg).unwrap();
        assert!(sol.converged, "residual {}", sol.residual);
        // PageRank solution is a probability vector
        assert!((vnorm1(&sol.x) - 1.0).abs() < 1e-7, "mass {}", vnorm1(&sol.x));
        assert!(sol.metrics["msgs_sent"] > 0);
    }

    #[test]
    fn round_robin_partition_works_too() {
        let p = problem(2);
        let cfg =
            DistributedConfig::new(Partition::round_robin(4, 2).unwrap()).with_tol(1e-12);
        let sol = solve_v2(&p, &cfg).unwrap();
        assert!(sol.converged);
        assert!(dist_inf(&sol.x, &p.exact_solution().unwrap()) < 1e-9);
    }

    #[test]
    fn latency_and_coalescing_conserve_fluid() {
        let g = power_law_web_graph(100, 4, 0.1, 13);
        let sys = pagerank_system(&g, 0.85, true).unwrap();
        let p = FixedPointProblem::new(sys.matrix.clone(), sys.b.clone()).unwrap();
        let mut cfg =
            DistributedConfig::new(Partition::contiguous(100, 4).unwrap()).with_tol(1e-10);
        cfg.latency = Some((Duration::from_micros(50), Duration::from_micros(300)));
        cfg.coalesce = crate::transport::CoalescePolicy {
            min_mass: 1e-4,
            max_entries: 64,
        };
        let sol = solve_v2(&p, &cfg).unwrap();
        assert!(sol.converged, "residual {}", sol.residual);
        assert!((vnorm1(&sol.x) - 1.0).abs() < 1e-7);
    }
}
