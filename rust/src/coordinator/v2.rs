//! V2 distributed scheme (§3.3): partial state + fluid transmission.
//!
//! Every `PID_k` keeps **only** its local slice `(B, H, F)_{i∈Ω_k}`.
//! Diffusing node `i ∈ Ω_k` with fluid `f = F_i` walks the *column*
//! `C_i(P)`: contributions `p_{ji}·f` to locally-owned j are applied
//! immediately; contributions to remote j are **coalesced** per destination
//! PID ("we can regroup (f₁+…+f_m)·p_{ji}; we don't need to know who sent
//! the fluid") and shipped over the bus, which retains every parcel until
//! acknowledged — fluid is never lost.
//!
//! Convergence is monitored *exactly*: every unit of fluid is either in a
//! PID's local F (published), held in a coalescing buffer (published by
//! its owner), or in flight (tracked by the bus) — the total is the
//! paper's "locally updated F_n plus all fluids being transmitted".
//!
//! With `cfg.adaptive` set, the leader additionally runs the §4.3 speed
//! adaptation while the solve is in progress: it windows the per-PID
//! update counters, and when one PID straggles it installs a new owner
//! map into the shared [`crate::partition::OwnershipTable`] — the workers
//! (the shared [`super::worker::WorkerCore`] loop) then hand the
//! reassigned `(H, B, F)` slices to each other over the bus without
//! stopping the diffusion.

use std::sync::Arc;
use std::time::Duration;

use super::adaptive::AdaptiveDriver;
use super::monitor::run_monitor_with;
use super::pool::WorkerPool;
use super::{DistributedConfig, DistributedSolution};
use crate::error::{DiterError, Result};
use crate::metrics::ConvergenceTrace;
use crate::solver::{FixedPointProblem, SequenceKind};

/// Solve with the V2 scheme. The worker lifecycle lives in the shared
/// [`WorkerPool`]; with `cfg.elastic` set, the pool's scheduler spawns
/// and retires PIDs while this solve is in progress.
pub fn solve_v2(
    problem: &FixedPointProblem,
    cfg: &DistributedConfig,
) -> Result<DistributedSolution> {
    let n = problem.n();
    if cfg.partition.n() != n {
        return Err(DiterError::shape("solve_v2 partition", n, cfg.partition.n()));
    }
    let k = cfg.partition.k();
    let problem = Arc::new(problem.clone());
    let mut pool = WorkerPool::new(problem.clone(), cfg.clone())?;
    let state = pool.state().clone();
    let table = pool.table().clone();
    let bus_mon = pool.monitor();
    let bus_metrics = pool.metrics().clone();

    // the elastic pool subsumes the shed-only driver (see its scheduler)
    let mut driver = if cfg.elastic.is_some() {
        None
    } else {
        cfg.adaptive
            .as_ref()
            .map(|a| AdaptiveDriver::new(a, k, cfg.tol))
    };
    let (converged_mon, trace, wall) = run_monitor_with(
        &state,
        &bus_mon,
        n,
        cfg.tol,
        cfg.max_wall,
        Duration::from_micros(200),
        3,
        |total| {
            if let Some(d) = driver.as_mut() {
                d.poll(
                    &table,
                    &state.update_counts(),
                    &state.published_values(),
                    total,
                    &bus_metrics,
                    Some(problem.matrix()),
                );
            }
            pool.poll(total);
        },
    );

    // lane-0 stride: the one-shot solve reads the base system even when
    // a caller configured extra query lanes (H slices are lane-blocked)
    let lanes = cfg.lanes.max(1);
    let mut x = vec![0.0; n];
    for (owned, values) in pool.finish()? {
        for (t, &i) in owned.iter().enumerate() {
            x[i] = values[t * lanes];
        }
    }
    let residual = problem.residual_norm(&x);
    Ok(DistributedSolution {
        residual,
        converged: converged_mon && residual <= cfg.tol * 10.0,
        cost: state.max_updates() as f64 / n as f64,
        total_updates: state.total_updates(),
        wall_secs: wall,
        trace: relabel(trace, "v2-total-fluid"),
        metrics: bus_metrics.snapshot(),
        x,
    })
}

fn relabel(mut t: ConvergenceTrace, name: &str) -> ConvergenceTrace {
    t.name = name.to_string();
    t
}

/// Sequence kinds that make sense for V2 (greedy reads local fluid, which
/// is exactly the information V2 keeps — the paper's recommended pairing).
pub fn default_v2_sequence() -> SequenceKind {
    SequenceKind::GreedyMaxFluid
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{pagerank_system, paper_matrix, power_law_web_graph};
    use crate::linalg::vec_ops::{dist_inf, norm1 as vnorm1};
    use crate::partition::Partition;

    fn problem(which: u8) -> FixedPointProblem {
        FixedPointProblem::from_linear_system(&paper_matrix(which), &[1.0; 4]).unwrap()
    }

    #[test]
    fn two_pids_solve_a1() {
        let p = problem(1);
        let cfg = DistributedConfig::new(Partition::contiguous(4, 2).unwrap()).with_tol(1e-12);
        let sol = solve_v2(&p, &cfg).unwrap();
        assert!(sol.converged, "residual {}", sol.residual);
        assert!(dist_inf(&sol.x, &p.exact_solution().unwrap()) < 1e-9);
    }

    #[test]
    fn coupled_matrices_converge() {
        for which in 2..=3u8 {
            let p = problem(which);
            let cfg =
                DistributedConfig::new(Partition::contiguous(4, 2).unwrap()).with_tol(1e-12);
            let sol = solve_v2(&p, &cfg).unwrap();
            assert!(sol.converged, "A({which}) residual {}", sol.residual);
            assert!(dist_inf(&sol.x, &p.exact_solution().unwrap()) < 1e-9);
        }
    }

    #[test]
    fn greedy_sequence_v2() {
        let p = problem(2);
        let cfg = DistributedConfig::new(Partition::contiguous(4, 2).unwrap())
            .with_tol(1e-12)
            .with_sequence(SequenceKind::GreedyMaxFluid);
        let sol = solve_v2(&p, &cfg).unwrap();
        assert!(sol.converged);
        assert!(dist_inf(&sol.x, &p.exact_solution().unwrap()) < 1e-9);
    }

    #[test]
    fn pagerank_web_graph_4_pids() {
        let g = power_law_web_graph(400, 5, 0.1, 11);
        let sys = pagerank_system(&g, 0.85, true).unwrap();
        let p = FixedPointProblem::new(sys.matrix.clone(), sys.b.clone()).unwrap();
        let cfg =
            DistributedConfig::new(Partition::contiguous(400, 4).unwrap()).with_tol(1e-10);
        let sol = solve_v2(&p, &cfg).unwrap();
        assert!(sol.converged, "residual {}", sol.residual);
        // PageRank solution is a probability vector
        assert!((vnorm1(&sol.x) - 1.0).abs() < 1e-7, "mass {}", vnorm1(&sol.x));
        assert!(sol.metrics["msgs_sent"] > 0);
    }

    #[test]
    fn round_robin_partition_works_too() {
        let p = problem(2);
        let cfg =
            DistributedConfig::new(Partition::round_robin(4, 2).unwrap()).with_tol(1e-12);
        let sol = solve_v2(&p, &cfg).unwrap();
        assert!(sol.converged);
        assert!(dist_inf(&sol.x, &p.exact_solution().unwrap()) < 1e-9);
    }

    #[test]
    fn adaptive_repartitioning_reaches_fixed_point() {
        // live §4.3: a throttled PID 0 plus an aggressive rebalance window
        // — the solve must still land exactly on the fixed point with all
        // fluid conserved through whatever handoffs fire
        let g = power_law_web_graph(200, 5, 0.1, 19);
        let sys = pagerank_system(&g, 0.85, true).unwrap();
        let p = FixedPointProblem::new(sys.matrix.clone(), sys.b.clone()).unwrap();
        let cfg = DistributedConfig::new(Partition::contiguous(200, 4).unwrap())
            .with_tol(1e-10)
            .with_sequence(SequenceKind::GreedyMaxFluid)
            .with_straggler(0, 30_000.0)
            .with_adaptive(crate::coordinator::AdaptiveConfig {
                interval: Duration::from_millis(10),
                ..Default::default()
            });
        let sol = solve_v2(&p, &cfg).unwrap();
        assert!(sol.converged, "residual {}", sol.residual);
        assert!(
            (vnorm1(&sol.x) - 1.0).abs() < 1e-7,
            "mass {} — fluid must be conserved through handoffs",
            vnorm1(&sol.x)
        );
    }

    #[test]
    fn latency_and_coalescing_conserve_fluid() {
        let g = power_law_web_graph(100, 4, 0.1, 13);
        let sys = pagerank_system(&g, 0.85, true).unwrap();
        let p = FixedPointProblem::new(sys.matrix.clone(), sys.b.clone()).unwrap();
        let mut cfg =
            DistributedConfig::new(Partition::contiguous(100, 4).unwrap()).with_tol(1e-10);
        cfg.latency = Some((Duration::from_micros(50), Duration::from_micros(300)));
        cfg.coalesce = crate::transport::CoalescePolicy {
            min_mass: 1e-4,
            max_entries: 64,
        };
        let sol = solve_v2(&p, &cfg).unwrap();
        assert!(sol.converged, "residual {}", sol.residual);
        assert!((vnorm1(&sol.x) - 1.0).abs() < 1e-7);
    }
}
