//! Global convergence monitor (§3.3 / §4.4).
//!
//! Each PID publishes its locally-known remaining fluid into a lock-free
//! slot; the leader sums the slots plus the transport's in-flight fluid.
//! For the V2 scheme this total is *exact* (fluid conservation: every unit
//! is either in some PID's F, in a coalescing buffer — counted by its
//! owner — or in flight). The monitor requires the threshold crossing to
//! hold for several consecutive polls before declaring convergence, which
//! closes the publish/poll race for V1's asynchronously-stale `r_k`.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::metrics::ConvergenceTrace;
use crate::transport::{AtomicF64, BusMonitor};

/// Shared leader/worker coordination state.
pub struct MonitorState {
    /// per-PID published remaining fluid (local F + held coalesce mass)
    pub published: Vec<AtomicF64>,
    /// per-PID scalar-update counters
    pub updates: Vec<AtomicU64>,
    /// set by the leader when the run must stop
    pub stop: AtomicBool,
    /// per-PID last-activity stamps, milliseconds since `origin`: the
    /// heartbeat side of failure detection. A worker stores its stamp
    /// once per loop iteration (one atomic store — no message, no
    /// allocation); the pool reads staleness. 0 = never stamped.
    beats: Vec<AtomicU64>,
    /// epoch for the beat stamps (process start of whoever built this)
    origin: Instant,
}

impl MonitorState {
    pub fn new(k: usize) -> Arc<Self> {
        Self::with_capacity(k, k)
    }

    /// `k` active slots plus headroom up to `cap` for PIDs an elastic
    /// pool may spawn later. Active slots start at ∞ (the total stays ∞
    /// until every initial PID published once); vacant slots start at 0
    /// — a not-yet-spawned worker holds no fluid, its share is counted by
    /// whichever PID (or the bus) currently holds it.
    pub fn with_capacity(k: usize, cap: usize) -> Arc<Self> {
        let cap = cap.max(k);
        Arc::new(Self {
            published: (0..cap)
                .map(|i| AtomicF64::new(if i < k { f64::INFINITY } else { 0.0 }))
                .collect(),
            updates: (0..cap).map(|_| AtomicU64::new(0)).collect(),
            stop: AtomicBool::new(false),
            beats: (0..cap).map(|_| AtomicU64::new(0)).collect(),
            origin: Instant::now(),
        })
    }

    /// Slot count (active + spawnable headroom).
    pub fn capacity(&self) -> usize {
        self.published.len()
    }

    pub fn publish(&self, k: usize, remaining: f64) {
        self.published[k].set(remaining);
    }

    pub fn add_updates(&self, k: usize, n: u64) {
        self.updates[k].fetch_add(n, Ordering::Relaxed);
    }

    /// Stamp worker `k`'s heartbeat (called once per worker loop
    /// iteration; a single relaxed store). `+1` keeps a stamp taken in
    /// the origin millisecond distinguishable from "never stamped".
    pub fn beat(&self, k: usize) {
        let ms = self.origin.elapsed().as_millis() as u64 + 1;
        self.beats[k].store(ms, Ordering::Relaxed);
    }

    /// Milliseconds since worker `k` last stamped, or None if it never
    /// has (a worker that has not booted yet is not stale).
    pub fn staleness_ms(&self, k: usize) -> Option<u64> {
        let last = self.beats[k].load(Ordering::Relaxed);
        if last == 0 {
            return None;
        }
        let now = self.origin.elapsed().as_millis() as u64 + 1;
        Some(now.saturating_sub(last))
    }

    /// Invalidate worker `k`'s published share on a liveness transition
    /// (death detected, slot respawning): a crashed worker's pre-death
    /// value is stale — pinning the slot to ∞ keeps the monitor total
    /// erring high, so recovery can never be declared quiescent on stale
    /// mass. Recovery's pre-publish of the reconstructed fluid replaces
    /// it. The beat stamp resets too, so the respawned worker is not
    /// born stale.
    pub fn invalidate(&self, k: usize) {
        self.published[k].set(f64::INFINITY);
        self.beats[k].store(0, Ordering::Relaxed);
    }

    pub fn should_stop(&self) -> bool {
        self.stop.load(Ordering::Acquire)
    }

    pub fn request_stop(&self) {
        self.stop.store(true, Ordering::Release);
    }

    /// Σ_k published r_k (∞ until every PID published once).
    pub fn published_total(&self) -> f64 {
        self.published.iter().map(AtomicF64::get).sum()
    }

    /// Per-PID published remaining fluid (the rebalancer's backlog view).
    pub fn published_values(&self) -> Vec<f64> {
        self.published.iter().map(AtomicF64::get).collect()
    }

    pub fn total_updates(&self) -> u64 {
        self.updates.iter().map(|u| u.load(Ordering::Relaxed)).sum()
    }

    /// Per-PID cumulative update counts (the adaptive controller's input).
    pub fn update_counts(&self) -> Vec<u64> {
        self.updates
            .iter()
            .map(|u| u.load(Ordering::Relaxed))
            .collect()
    }

    pub fn max_updates(&self) -> u64 {
        self.updates
            .iter()
            .map(|u| u.load(Ordering::Relaxed))
            .max()
            .unwrap_or(0)
    }
}

/// Leader-side poll loop: waits until total fluid < tol (stable for
/// `stable_polls` polls) or the deadline passes, then raises `stop`.
/// Returns (converged, trace of total-fluid samples, wall seconds).
pub fn run_monitor(
    state: &MonitorState,
    bus: &BusMonitor,
    n: usize,
    tol: f64,
    max_wall: Duration,
    poll: Duration,
    stable_polls: usize,
) -> (bool, ConvergenceTrace, f64) {
    run_monitor_with(state, bus, n, tol, max_wall, poll, stable_polls, |_| {})
}

/// [`run_monitor`] with a per-poll hook: `on_poll(total_fluid)` runs once
/// per sample, before the convergence check — the leader-side seam where
/// the adaptive repartitioning driver observes progress and installs
/// ownership changes while the workers keep diffusing.
#[allow(clippy::too_many_arguments)]
pub fn run_monitor_with(
    state: &MonitorState,
    bus: &BusMonitor,
    n: usize,
    tol: f64,
    max_wall: Duration,
    poll: Duration,
    stable_polls: usize,
    mut on_poll: impl FnMut(f64),
) -> (bool, ConvergenceTrace, f64) {
    let t0 = Instant::now();
    let deadline = t0 + max_wall;
    let mut trace = ConvergenceTrace::new("monitor-total-fluid");
    let mut stable = 0usize;
    let mut converged = false;
    loop {
        let total = state.published_total() + bus.inflight_or_zero();
        let cost = state.max_updates() as f64 / n as f64;
        if total.is_finite() {
            trace.push(cost, total);
        }
        on_poll(total);
        // quiescence: no message may be awaiting application — a PID that
        // hasn't absorbed a peer update yet publishes a stale (possibly
        // zero) r_k, so `total` alone can transiently under-count.
        if total < tol && bus.undelivered() == 0 {
            stable += 1;
            if stable >= stable_polls {
                converged = true;
                break;
            }
        } else {
            stable = 0;
        }
        if Instant::now() >= deadline {
            break;
        }
        std::thread::sleep(poll);
    }
    state.request_stop();
    (converged, trace, t0.elapsed().as_secs_f64())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transport::{bus, BusConfig};

    #[test]
    fn publish_and_total() {
        let s = MonitorState::new(2);
        assert!(s.published_total().is_infinite());
        s.publish(0, 0.5);
        s.publish(1, 0.25);
        assert!((s.published_total() - 0.75).abs() < 1e-15);
        s.add_updates(0, 10);
        s.add_updates(1, 4);
        assert_eq!(s.total_updates(), 14);
        assert_eq!(s.max_updates(), 10);
    }

    #[test]
    fn capacity_slots_start_drained() {
        let s = MonitorState::with_capacity(2, 4);
        assert_eq!(s.capacity(), 4);
        assert!(s.published_total().is_infinite(), "active slots gate the total");
        s.publish(0, 0.5);
        s.publish(1, 0.25);
        // vacant slots contribute nothing until a spawned worker publishes
        assert!((s.published_total() - 0.75).abs() < 1e-15);
        s.publish(3, 0.125);
        assert!((s.published_total() - 0.875).abs() < 1e-15);
        s.add_updates(3, 7);
        assert_eq!(s.update_counts(), vec![0, 0, 0, 7]);
    }

    #[test]
    fn beats_and_invalidation() {
        let s = MonitorState::new(2);
        assert_eq!(s.staleness_ms(0), None, "never stamped = not stale");
        s.beat(0);
        assert!(s.staleness_ms(0).unwrap() < 1_000);
        s.publish(0, 0.25);
        s.publish(1, 0.25);
        s.invalidate(0);
        assert!(s.published_total().is_infinite(), "invalidation pins ∞");
        assert_eq!(s.staleness_ms(0), None, "beat stamp reset with the slot");
        s.publish(0, 0.5);
        assert!((s.published_total() - 0.75).abs() < 1e-15);
    }

    #[test]
    fn monitor_stops_on_convergence() {
        let s = MonitorState::new(1);
        let (eps, _m) = bus::<u8>(1, &BusConfig::default());
        let mon = crate::transport::monitor_of(&eps[0]);
        s.publish(0, 0.0);
        let (converged, trace, _wall) = run_monitor(
            &s,
            &mon,
            4,
            1e-9,
            Duration::from_secs(5),
            Duration::from_micros(100),
            3,
        );
        assert!(converged);
        assert!(s.should_stop());
        assert!(!trace.points.is_empty());
    }

    #[test]
    fn monitor_times_out() {
        let s = MonitorState::new(1);
        let (eps, _m) = bus::<u8>(1, &BusConfig::default());
        let mon = crate::transport::monitor_of(&eps[0]);
        s.publish(0, 1.0); // never converges
        let (converged, _trace, wall) = run_monitor(
            &s,
            &mon,
            4,
            1e-9,
            Duration::from_millis(50),
            Duration::from_micros(200),
            3,
        );
        assert!(!converged);
        assert!(wall >= 0.049);
    }
}
