//! Multi-tenant query serving (DESIGN.md §10): the [`QuerySet`] registry
//! and the admission-controlled [`ServeEngine`].
//!
//! D-iteration is linear in the source vector b, so one engine — one
//! matrix, one worker pool — can serve many personalized-PageRank /
//! seeded-diffusion queries concurrently by diffusing a *block* of
//! fluids instead of one. Each live query owns a **lane**: a slot in the
//! workers' lane-blocked fluid/history storage (`f[t * lanes + lane]`).
//! Lane 0 is always the base problem; query lanes are recycled across
//! tenants, distinguished on the wire by a monotonically increasing
//! global **query id** so stale parcels from an evicted tenant can never
//! leak into the next one.
//!
//! The registry is the shared contract between the serving loop and the
//! workers:
//!
//! * the serving loop admits/evicts queries (cold path, mutex-guarded)
//!   and watches per-lane convergence via [`QuerySet::lane_total`];
//! * workers read the lane↔qid table (atomics, hot path), claim seed
//!   fluid exactly once per seed, publish per-lane fluid mass, and keep
//!   the per-lane in-flight account exact across parcels they flush and
//!   absorb.
//!
//! Per-lane accounting errs **high**, never low (the same discipline as
//! the aggregate monitor): a query is only declared served when every
//! worker's published lane mass, the lane's in-flight parcel mass, and
//! its still-unclaimed seed mass together fall under its ε.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use super::stream::StreamingEngine;
use super::DistributedConfig;
use crate::error::Result;
use crate::graph::{Mutation, MutableDigraph};
use crate::metrics::RateMeter;
use crate::solver::SequenceKind;
use crate::transport::AtomicF64;

/// Serving-layer counters/gauges, registered by the pool alongside
/// [`super::worker::WORKER_METRICS`] so `serve` runs report them in the
/// same stats block.
pub const QUERY_METRICS: [&str; 4] = [
    "queries_admitted",
    "queries_served",
    "queries_rejected",
    "active_lanes",
];

/// Sentinel qid for a lane with no tenant. Workers drop parcels whose
/// qid doesn't match the lane's current qid, so `FREE_LANE` (never a
/// real qid) makes a freed lane inert.
pub const FREE_LANE: u32 = u32::MAX;

/// Lifecycle of one query (ISSUE: Admitted → Converging → Served →
/// Evicted). `Converging` is entered as soon as any seed fluid is
/// claimed; `Evicted` without `Served` means the deadline expired or the
/// caller cancelled.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QueryState {
    Queued,
    Admitted,
    Converging,
    Served,
    Evicted,
}

/// One seeded-diffusion query: initial fluid placed on `seeds`, run
/// until the query lane's total outstanding fluid falls under `eps`.
#[derive(Clone, Debug)]
pub struct Query {
    /// (coordinate, initial fluid mass) pairs.
    pub seeds: Vec<(usize, f64)>,
    /// Per-query convergence target on the lane's total fluid.
    pub eps: f64,
    /// Evict unserved once this much wall time has passed since
    /// admission (None = no deadline).
    pub deadline: Option<Duration>,
}

impl Query {
    /// Personalized PageRank teleporting to `seeds`: for the patched
    /// (column-stochastic + dangling-fixed) system with damping `d`,
    /// seed mass `(1-d)/|seeds|` per seed makes ‖x_q‖₁ = 1 — the same
    /// unit-mass invariant the base PageRank lane satisfies.
    pub fn ppr(seeds: &[usize], damping: f64, eps: f64) -> Self {
        let w = (1.0 - damping) / seeds.len().max(1) as f64;
        Query {
            seeds: seeds.iter().map(|&s| (s, w)).collect(),
            eps,
            deadline: None,
        }
    }

    /// Total |seed| mass of this query.
    pub fn seed_mass(&self) -> f64 {
        self.seeds.iter().map(|&(_, m)| m.abs()).sum()
    }
}

/// Mutable per-lane state, engine/worker shared under a mutex. Only
/// cold paths lock it: admission, eviction, seed claiming (which stops
/// as soon as the global unclaimed counter hits zero), and the serving
/// loop's ε/deadline checks.
#[derive(Debug)]
struct LaneSlot {
    qid: u32,
    query: Option<Query>,
    state: QueryState,
    claimed: Vec<bool>,
    admitted_at: Option<Instant>,
}

/// Completion record for a finished (served or evicted) query.
#[derive(Clone, Debug)]
pub struct QueryRecord {
    pub qid: u32,
    pub lane: usize,
    pub state: QueryState,
    /// Wall seconds from admission to crossing ε (None when evicted
    /// unserved).
    pub time_to_eps_secs: Option<f64>,
}

/// The query registry shared by the serving loop and every worker.
///
/// Hot-path state is atomic (lane↔qid table, per-lane published /
/// in-flight / unclaimed mass); per-lane descriptors live behind small
/// mutexes that only cold paths take.
pub struct QuerySet {
    lanes: usize,
    cap_pids: usize,
    /// Bumped on every admit/evict; workers resync their cached lane
    /// table when it moves.
    version: AtomicU64,
    next_qid: AtomicU32,
    /// Current qid per lane: 0 = base (lane 0 only), FREE_LANE = empty.
    lane_qids: Vec<AtomicU32>,
    /// Per-lane |mass| charged at parcel flush, released on absorb.
    inflight: Vec<AtomicF64>,
    /// Per-lane seed mass not yet claimed by any worker (errs high:
    /// decremented only after the claiming worker has published the
    /// claimed fluid).
    unclaimed: Vec<AtomicF64>,
    /// Count of individual unclaimed seeds across all lanes — the one
    /// atomic workers poll per step to keep the claim scan off the
    /// steady-state hot path.
    unclaimed_seeds: AtomicU64,
    /// Per-(pid, lane) published fluid mass, flat `pid * lanes + lane`.
    published: Vec<AtomicF64>,
    slots: Vec<Mutex<LaneSlot>>,
    completed: Mutex<Vec<QueryRecord>>,
}

impl std::fmt::Debug for QuerySet {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("QuerySet")
            .field("lanes", &self.lanes)
            .field("cap_pids", &self.cap_pids)
            .field("version", &self.version.load(Ordering::Relaxed))
            .field("active", &self.active_lanes())
            .finish()
    }
}

impl QuerySet {
    /// `lanes` counts lane 0 (the base problem); `lanes - 1` queries can
    /// be in flight at once. `cap_pids` must cover the pool's worker
    /// capacity (`ElasticConfig::max_workers` or K).
    pub fn new(lanes: usize, cap_pids: usize) -> Self {
        assert!(lanes >= 1, "lane 0 (the base problem) always exists");
        assert!(cap_pids >= 1);
        let lane_qids: Vec<AtomicU32> = (0..lanes)
            .map(|l| AtomicU32::new(if l == 0 { 0 } else { FREE_LANE }))
            .collect();
        QuerySet {
            lanes,
            cap_pids,
            version: AtomicU64::new(0),
            next_qid: AtomicU32::new(1),
            lane_qids,
            inflight: (0..lanes).map(|_| AtomicF64::new(0.0)).collect(),
            unclaimed: (0..lanes).map(|_| AtomicF64::new(0.0)).collect(),
            unclaimed_seeds: AtomicU64::new(0),
            published: (0..lanes * cap_pids).map(|_| AtomicF64::new(0.0)).collect(),
            slots: (0..lanes)
                .map(|l| {
                    Mutex::new(LaneSlot {
                        qid: if l == 0 { 0 } else { FREE_LANE },
                        query: None,
                        state: QueryState::Queued,
                        claimed: Vec::new(),
                        admitted_at: None,
                    })
                })
                .collect(),
            completed: Mutex::new(Vec::new()),
        }
    }

    pub fn lanes(&self) -> usize {
        self.lanes
    }

    pub fn version(&self) -> u64 {
        self.version.load(Ordering::Acquire)
    }

    /// Allocate the next global query id (monotonic, never reused).
    pub fn next_qid(&self) -> u32 {
        let qid = self.next_qid.fetch_add(1, Ordering::Relaxed);
        assert!(qid != FREE_LANE, "query id space exhausted");
        qid
    }

    pub fn lane_qid(&self, lane: usize) -> u32 {
        self.lane_qids[lane].load(Ordering::Acquire)
    }

    /// Fill `out` with the current lane→qid table (workers cache this
    /// and refile on a version bump).
    pub fn snapshot_qids(&self, out: &mut Vec<u32>) {
        out.clear();
        for l in 0..self.lanes {
            out.push(self.lane_qids[l].load(Ordering::Acquire));
        }
    }

    /// Fill `out` with each lane's ε (0.0 for lane 0 and free lanes —
    /// workers use this to detect ε-crossings, and 0.0 disables the
    /// trigger).
    pub fn snapshot_eps(&self, out: &mut Vec<f64>) {
        out.clear();
        for l in 0..self.lanes {
            let slot = self.slots[l].lock().unwrap();
            out.push(match (&slot.query, slot.qid) {
                (Some(q), qid) if qid != FREE_LANE => q.eps,
                _ => 0.0,
            });
        }
    }

    /// Install `q` into a free lane. Returns the (lane, qid) pair, or
    /// None when every query lane is occupied.
    pub fn admit(&self, q: Query, qid: u32) -> Option<usize> {
        for lane in 1..self.lanes {
            if self.lane_qids[lane].load(Ordering::Acquire) != FREE_LANE {
                continue;
            }
            let mut slot = self.slots[lane].lock().unwrap();
            if slot.qid != FREE_LANE {
                continue; // raced with another admitter
            }
            let seed_mass = q.seed_mass();
            let n_seeds = q.seeds.len() as u64;
            slot.qid = qid;
            slot.claimed = vec![false; q.seeds.len()];
            slot.query = Some(q);
            slot.state = QueryState::Admitted;
            slot.admitted_at = Some(Instant::now());
            // ordering: the accounting (inflight reset, unclaimed mass)
            // must be in place before the qid goes live — a worker that
            // sees the new qid must also see the seeds it may claim
            self.inflight[lane].set(0.0);
            self.unclaimed[lane].set(seed_mass);
            self.lane_qids[lane].store(qid, Ordering::Release);
            self.unclaimed_seeds.fetch_add(n_seeds, Ordering::Release);
            drop(slot);
            self.version.fetch_add(1, Ordering::Release);
            return Some(lane);
        }
        None
    }

    /// Free `lane`, recording the tenant's final state. Workers zero
    /// the lane's fluid/history and drop its pending parcels at their
    /// next sync; parcels already in flight die at the receiver's qid
    /// check.
    pub fn evict(&self, lane: usize, state: QueryState, time_to_eps_secs: Option<f64>) {
        assert!(lane > 0 && lane < self.lanes, "lane 0 cannot be evicted");
        let mut slot = self.slots[lane].lock().unwrap();
        if slot.qid == FREE_LANE {
            return;
        }
        let qid = slot.qid;
        // un-count the seeds nobody claimed
        let pending = slot.claimed.iter().filter(|&&c| !c).count() as u64;
        if pending > 0 {
            self.unclaimed_seeds.fetch_sub(pending, Ordering::AcqRel);
        }
        slot.qid = FREE_LANE;
        slot.query = None;
        slot.state = state;
        slot.admitted_at = None;
        slot.claimed.clear();
        // qid goes dead first, then the accounting resets: a straggling
        // charge against the old qid is refused by the guard below
        self.lane_qids[lane].store(FREE_LANE, Ordering::Release);
        self.inflight[lane].set(0.0);
        self.unclaimed[lane].set(0.0);
        for pid in 0..self.cap_pids {
            self.published[pid * self.lanes + lane].set(0.0);
        }
        drop(slot);
        self.completed.lock().unwrap().push(QueryRecord {
            qid,
            lane,
            state,
            time_to_eps_secs,
        });
        self.version.fetch_add(1, Ordering::Release);
    }

    /// Guarded per-lane in-flight charge/release: a no-op unless `qid`
    /// is still the lane's tenant, so a parcel flushed for an evicted
    /// query can neither pollute the next tenant's account nor leak.
    pub fn add_inflight(&self, lane: usize, qid: u32, delta: f64) {
        if self.lane_qids[lane].load(Ordering::Acquire) == qid {
            self.inflight[lane].add(delta);
        }
    }

    /// Worker `pid`'s published fluid mass for `lane` (absolute value,
    /// like `MonitorState::publish`).
    pub fn publish_lane(&self, pid: usize, lane: usize, mass: f64) {
        self.published[pid * self.lanes + lane].set(mass);
    }

    /// Zero every lane published by `pid` — the pool calls this when the
    /// worker retires, mirroring its `state.publish(pid, 0.0)`.
    pub fn zero_published_pid(&self, pid: usize) {
        for lane in 0..self.lanes {
            self.published[pid * self.lanes + lane].set(0.0);
        }
    }

    /// The lane's total outstanding fluid estimate: published by every
    /// worker + in flight + still-unclaimed seed mass. Errs high, never
    /// low, so `lane_total < eps` is a safe serve condition.
    pub fn lane_total(&self, lane: usize) -> f64 {
        let mut total = self.inflight[lane].get().max(0.0) + self.unclaimed[lane].get().max(0.0);
        for pid in 0..self.cap_pids {
            total += self.published[pid * self.lanes + lane].get();
        }
        total
    }

    /// Number of lanes currently serving a query.
    pub fn active_lanes(&self) -> usize {
        (1..self.lanes)
            .filter(|&l| self.lane_qids[l].load(Ordering::Acquire) != FREE_LANE)
            .count()
    }

    /// Count of seeds not yet claimed by any worker — the one-atomic
    /// fast check workers make per step.
    pub fn unclaimed_seed_count(&self) -> u64 {
        self.unclaimed_seeds.load(Ordering::Acquire)
    }

    /// Claim every unclaimed seed currently held by the caller
    /// (`holds(coord)`), appending `(lane, qid, coord, mass)` to `out`.
    /// The caller must inject each seed's fluid, publish, then call
    /// [`QuerySet::seed_settled`] per claim — in that order, so the
    /// global estimate never dips below the truth.
    pub fn claim_seeds(
        &self,
        mut holds: impl FnMut(usize) -> bool,
        out: &mut Vec<(usize, u32, usize, f64)>,
    ) {
        for lane in 1..self.lanes {
            if self.unclaimed[lane].get() == 0.0 {
                continue;
            }
            let mut slot = self.slots[lane].lock().unwrap();
            if slot.qid == FREE_LANE {
                continue;
            }
            let qid = slot.qid;
            let LaneSlot {
                ref query,
                ref mut claimed,
                ref mut state,
                ..
            } = *slot;
            if let Some(q) = query {
                for (i, &(coord, mass)) in q.seeds.iter().enumerate() {
                    if !claimed[i] && holds(coord) {
                        claimed[i] = true;
                        *state = QueryState::Converging;
                        out.push((lane, qid, coord, mass));
                    }
                }
            }
        }
    }

    /// Settle one claimed seed *after* its fluid is live in the
    /// claimer's published mass.
    pub fn seed_settled(&self, lane: usize, mass: f64) {
        self.unclaimed[lane].add(-mass.abs());
        self.unclaimed_seeds.fetch_sub(1, Ordering::AcqRel);
    }

    /// The dense RHS vector for `lane` (length `n`), and mark every
    /// seed claimed with the unclaimed account zeroed — the gather
    /// rebase discards F and recomputes it from the full per-lane B, so
    /// the rebase itself injects any seeds still pending.
    pub fn lane_b_claim_all(&self, lane: usize, n: usize) -> Option<Vec<f64>> {
        let mut slot = self.slots[lane].lock().unwrap();
        if slot.qid == FREE_LANE {
            return None;
        }
        let mut pending = 0u64;
        for c in slot.claimed.iter_mut() {
            if !*c {
                pending += 1;
                *c = true;
            }
        }
        let q = slot.query.as_ref()?;
        let mut b = vec![0.0; n];
        for &(coord, mass) in &q.seeds {
            if coord < n {
                b[coord] += mass;
            }
        }
        slot.state = QueryState::Converging;
        drop(slot);
        if pending > 0 {
            self.unclaimed_seeds.fetch_sub(pending, Ordering::AcqRel);
        }
        self.unclaimed[lane].set(0.0);
        Some(b)
    }

    /// The lane's ε target (None when free).
    pub fn lane_eps(&self, lane: usize) -> Option<f64> {
        let slot = self.slots[lane].lock().unwrap();
        slot.query.as_ref().map(|q| q.eps)
    }

    /// Seconds since the lane's tenant was admitted (None when free).
    pub fn lane_age(&self, lane: usize) -> Option<f64> {
        let slot = self.slots[lane].lock().unwrap();
        slot.admitted_at.map(|t| t.elapsed().as_secs_f64())
    }

    /// True when the lane's tenant has a deadline and it has expired.
    pub fn deadline_expired(&self, lane: usize) -> bool {
        let slot = self.slots[lane].lock().unwrap();
        match (&slot.query, slot.admitted_at) {
            (Some(q), Some(at)) => q.deadline.is_some_and(|d| at.elapsed() > d),
            _ => false,
        }
    }

    /// Drain the completion log (served and evicted queries, in order).
    pub fn take_completed(&self) -> Vec<QueryRecord> {
        std::mem::take(&mut *self.completed.lock().unwrap())
    }
}

/// Admission-control knobs for [`ServeEngine`].
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Queries waiting for a lane beyond the in-flight cap; a submit
    /// past this is rejected outright.
    pub queue_cap: usize,
    /// ε for queries that don't specify one.
    pub default_eps: f64,
    /// Deadline for queries that don't specify one.
    pub default_deadline: Option<Duration>,
    /// Consecutive polls a lane must stay under ε before it is served
    /// (mirrors the aggregate monitor's stability requirement).
    pub stable_polls: u32,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            queue_cap: 32,
            default_eps: 1e-8,
            default_deadline: None,
            stable_polls: 3,
        }
    }
}

/// A finished query handed back by [`ServeEngine::poll`].
#[derive(Clone, Debug)]
pub struct ServedQuery {
    pub qid: u32,
    pub lane: usize,
    pub state: QueryState,
    pub time_to_eps_secs: Option<f64>,
    /// The per-query solution readout (None when evicted unserved).
    pub x: Option<Vec<f64>>,
}

/// The serving loop: a [`StreamingEngine`] whose workers diffuse
/// `lanes` fluids at once, fronted by queue-or-reject admission
/// control. Queries keep flowing while churn epochs, ownership
/// handoffs, and elastic spawn/retire run underneath — admission never
/// waits for the engine to converge.
pub struct ServeEngine {
    engine: StreamingEngine,
    qs: Arc<QuerySet>,
    cfg: ServeConfig,
    queue: VecDeque<(u32, Query)>,
    /// Per-lane consecutive below-ε polls.
    stable: Vec<u32>,
    freshness: RateMeter,
    last_poll: Instant,
    admitted: u64,
    served: u64,
    rejected: u64,
}

impl ServeEngine {
    /// Build a serving engine with `query_lanes` concurrent query slots
    /// on top of the streaming PageRank system for `graph`. Forces the
    /// greedy sequence (multi-lane diffusion requires the heap's
    /// largest-fluid-anywhere rule) and installs the shared
    /// [`QuerySet`] into the worker config.
    pub fn new(
        graph: MutableDigraph,
        damping: f64,
        patch_dangling: bool,
        mut dist: DistributedConfig,
        cfg: ServeConfig,
        query_lanes: usize,
    ) -> Result<Self> {
        assert!(query_lanes >= 1, "need at least one query lane");
        let k = dist.partition.k();
        let cap = dist
            .elastic
            .as_ref()
            .map(|e| e.max_workers.max(k))
            .unwrap_or(k);
        let qs = Arc::new(QuerySet::new(query_lanes + 1, cap));
        dist.lanes = query_lanes + 1;
        dist.queries = Some(qs.clone());
        dist.sequence = SequenceKind::GreedyMaxFluid;
        let engine = StreamingEngine::new(graph, damping, patch_dangling, dist)?;
        Ok(ServeEngine {
            engine,
            qs,
            cfg,
            queue: VecDeque::new(),
            stable: vec![0; query_lanes + 1],
            freshness: RateMeter::new(0.4),
            last_poll: Instant::now(),
            admitted: 0,
            served: 0,
            rejected: 0,
        })
    }

    pub fn query_set(&self) -> &Arc<QuerySet> {
        &self.qs
    }

    pub fn engine(&self) -> &StreamingEngine {
        &self.engine
    }

    pub fn engine_mut(&mut self) -> &mut StreamingEngine {
        &mut self.engine
    }

    /// Smoothed queries-served-per-second (None until the first serve).
    pub fn freshness(&self) -> Option<f64> {
        self.freshness.rate()
    }

    pub fn counts(&self) -> (u64, u64, u64) {
        (self.admitted, self.served, self.rejected)
    }

    /// Submit a query: admitted straight into a lane when one is free,
    /// queued while all lanes are busy, rejected (None) when the queue
    /// is full. Never blocks on engine state.
    pub fn submit(&mut self, mut q: Query) -> Option<u32> {
        if q.eps <= 0.0 {
            q.eps = self.cfg.default_eps;
        }
        if q.deadline.is_none() {
            q.deadline = self.cfg.default_deadline;
        }
        if self.queue.len() >= self.cfg.queue_cap {
            self.rejected += 1;
            self.engine.metrics().incr("queries_rejected");
            return None;
        }
        let qid = self.qs.next_qid();
        self.queue.push_back((qid, q));
        self.try_admit();
        Some(qid)
    }

    fn try_admit(&mut self) {
        while let Some((qid, q)) = self.queue.front() {
            match self.qs.admit(q.clone(), *qid) {
                Some(lane) => {
                    self.stable[lane] = 0;
                    self.queue.pop_front();
                    self.admitted += 1;
                    self.engine.metrics().incr("queries_admitted");
                }
                None => break, // all lanes busy; stay queued
            }
        }
        self.engine
            .metrics()
            .set("active_lanes", self.qs.active_lanes() as u64);
    }

    /// Apply a graph-mutation batch and rebase the workers *without*
    /// blocking until reconvergence — the serving loop keeps admitting
    /// and completing queries while the new epoch's fluid settles.
    pub fn apply_mutations(&mut self, batch: &[Mutation]) -> Result<usize> {
        self.engine.apply_batch_async(batch)
    }

    /// One non-blocking serving tick: pump the engine's schedulers,
    /// evict expired tenants, complete lanes that have stayed under
    /// their ε, and admit from the queue into freed lanes. Returns the
    /// queries that finished during this tick.
    pub fn poll(&mut self) -> Result<Vec<ServedQuery>> {
        self.engine.pump();
        let mut done = Vec::new();
        let lanes = self.qs.lanes();
        for lane in 1..lanes {
            let qid = self.qs.lane_qid(lane);
            if qid == FREE_LANE {
                continue;
            }
            if self.qs.deadline_expired(lane) {
                self.qs.evict(lane, QueryState::Evicted, None);
                self.stable[lane] = 0;
                done.push(ServedQuery {
                    qid,
                    lane,
                    state: QueryState::Evicted,
                    time_to_eps_secs: None,
                    x: None,
                });
                continue;
            }
            let eps = match self.qs.lane_eps(lane) {
                Some(e) => e,
                None => continue,
            };
            if self.qs.lane_total(lane) < eps {
                self.stable[lane] += 1;
            } else {
                self.stable[lane] = 0;
            }
            if self.stable[lane] >= self.cfg.stable_polls {
                let tte = self.qs.lane_age(lane);
                let x = self.engine.gather_lane(lane)?;
                // re-check: the lane must still be under ε after the
                // readout (a churn epoch between the check and the
                // gather could have re-excited it)
                if self.qs.lane_total(lane) >= eps {
                    self.stable[lane] = 0;
                    continue;
                }
                self.qs.evict(lane, QueryState::Served, tte);
                self.stable[lane] = 0;
                self.served += 1;
                self.engine.metrics().incr("queries_served");
                done.push(ServedQuery {
                    qid,
                    lane,
                    state: QueryState::Served,
                    time_to_eps_secs: tte,
                    x: Some(x),
                });
            }
        }
        if !done.is_empty() {
            let secs = self.last_poll.elapsed().as_secs_f64();
            let served = done
                .iter()
                .filter(|d| d.state == QueryState::Served)
                .count() as u64;
            self.freshness.record(served, secs);
            self.last_poll = Instant::now();
        }
        self.try_admit();
        Ok(done)
    }

    /// Poll until every submitted query has completed (served or
    /// evicted) or `deadline` passes. Returns everything that finished.
    pub fn drain(&mut self, deadline: Duration) -> Result<Vec<ServedQuery>> {
        let start = Instant::now();
        let mut all = Vec::new();
        while (!self.queue.is_empty() || self.qs.active_lanes() > 0)
            && start.elapsed() < deadline
        {
            all.extend(self.poll()?);
            std::thread::sleep(Duration::from_micros(200));
        }
        Ok(all)
    }

    /// Number of queries waiting for a lane.
    pub fn queued(&self) -> usize {
        self.queue.len()
    }

    /// Shut the engine down, returning the underlying stream summary.
    pub fn finish(self) -> Result<super::stream::StreamSummary> {
        self.engine.finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn admit_evict_lifecycle_and_qids_are_unique() {
        let qs = QuerySet::new(3, 2);
        assert_eq!(qs.active_lanes(), 0);
        let q1 = qs.next_qid();
        let q2 = qs.next_qid();
        assert_ne!(q1, q2);
        let l1 = qs.admit(Query::ppr(&[0], 0.85, 1e-8), q1).unwrap();
        let l2 = qs.admit(Query::ppr(&[1], 0.85, 1e-8), q2).unwrap();
        assert_ne!(l1, l2);
        assert_eq!(qs.active_lanes(), 2);
        // all lanes busy
        assert!(qs.admit(Query::ppr(&[2], 0.85, 1e-8), qs.next_qid()).is_none());
        qs.evict(l1, QueryState::Served, Some(0.5));
        assert_eq!(qs.active_lanes(), 1);
        assert_eq!(qs.lane_qid(l1), FREE_LANE);
        // freed lane is reusable with a fresh qid
        let q3 = qs.next_qid();
        assert_eq!(qs.admit(Query::ppr(&[2], 0.85, 1e-8), q3), Some(l1));
        assert_eq!(qs.lane_qid(l1), q3);
        let rec = qs.take_completed();
        assert_eq!(rec.len(), 1);
        assert_eq!(rec[0].qid, q1);
        assert_eq!(rec[0].state, QueryState::Served);
    }

    #[test]
    fn lane_total_errs_high_through_the_claim_protocol() {
        let qs = QuerySet::new(2, 1);
        let qid = qs.next_qid();
        let lane = qs.admit(Query::ppr(&[3, 4], 0.8, 1e-9), qid).unwrap();
        let seed_mass = 0.2; // (1 - 0.8) split over 2 seeds, 0.1 each
        assert!((qs.lane_total(lane) - seed_mass).abs() < 1e-12);
        assert_eq!(qs.unclaimed_seed_count(), 2);
        // worker claims the seed it holds (coord 3 only)
        let mut claims = Vec::new();
        qs.claim_seeds(|c| c == 3, &mut claims);
        assert_eq!(claims.len(), 1);
        let (l, q, coord, mass) = claims[0];
        assert_eq!((l, q, coord), (lane, qid, 3));
        // worker injects + publishes BEFORE settling: total double-counts
        // (errs high), never dips
        qs.publish_lane(0, lane, mass.abs());
        assert!(qs.lane_total(lane) > seed_mass - 1e-12);
        qs.seed_settled(lane, mass);
        assert_eq!(qs.unclaimed_seed_count(), 1);
        assert!((qs.lane_total(lane) - seed_mass).abs() < 1e-12);
        // re-claim finds nothing new for the same holder
        let mut again = Vec::new();
        qs.claim_seeds(|c| c == 3, &mut again);
        assert!(again.is_empty());
    }

    #[test]
    fn inflight_guard_refuses_stale_qids() {
        let qs = QuerySet::new(2, 1);
        let qid = qs.next_qid();
        let lane = qs.admit(Query::ppr(&[0], 0.85, 1e-9), qid).unwrap();
        qs.add_inflight(lane, qid, 0.5);
        assert!(qs.lane_total(lane) > 0.5);
        qs.evict(lane, QueryState::Evicted, None);
        // charge against the dead tenant: refused, account stays clean
        qs.add_inflight(lane, qid, 0.25);
        let qid2 = qs.next_qid();
        let lane2 = qs.admit(Query::ppr(&[1], 0.85, 1e-9), qid2).unwrap();
        assert_eq!(lane2, lane);
        assert!((qs.lane_total(lane) - 0.15).abs() < 1e-12); // just the new seeds
    }

    #[test]
    fn gather_claims_everything_at_once() {
        let qs = QuerySet::new(2, 1);
        let qid = qs.next_qid();
        let lane = qs.admit(Query::ppr(&[1, 3], 0.85, 1e-9), qid).unwrap();
        let b = qs.lane_b_claim_all(lane, 5).unwrap();
        assert!((b[1] - 0.075).abs() < 1e-12);
        assert!((b[3] - 0.075).abs() < 1e-12);
        assert_eq!(qs.unclaimed_seed_count(), 0);
        assert_eq!(qs.lane_total(lane), 0.0);
        let mut claims = Vec::new();
        qs.claim_seeds(|_| true, &mut claims);
        assert!(claims.is_empty());
    }
}
