//! §4.3 speed adaptation: "when the PIDs advance at very different speeds
//! (monitoring T_k), we can think of splitting the set Ω_k associated to
//! the slowest PID_k or possibly regrouping Ω_k associated to the fastest
//! PID_k".
//!
//! [`AdaptiveController`] watches per-PID progress (scalar updates per
//! wall second, as published through [`super::monitor::MonitorState`]) and
//! recommends repartitioning actions. The mechanics (exact-cover-preserving
//! [`Partition::split_part`] / [`Partition::merge_parts`]) live in the
//! partition module; this controller supplies the *policy*.

use crate::partition::Partition;

/// A recommended repartitioning action.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Adaptation {
    /// everything within tolerance: keep the current partition
    Keep,
    /// split the slowest PID's set (it is the straggler)
    Split { pid: usize },
    /// merge the two fastest PIDs' sets (they idle waiting for stragglers)
    Merge { fast_a: usize, fast_b: usize },
}

/// Policy knobs.
#[derive(Clone, Copy, Debug)]
pub struct AdaptivePolicy {
    /// recommend a split when the slowest PID's *per-coordinate* rate is
    /// below `split_ratio` × the median rate (straggler detection)
    pub split_ratio: f64,
    /// recommend a merge when the two fastest PIDs are each above
    /// `merge_ratio` × the median rate
    pub merge_ratio: f64,
    /// never shrink a part below this many coordinates by splitting
    pub min_part: usize,
    /// never grow the PID count beyond this
    pub max_pids: usize,
}

impl Default for AdaptivePolicy {
    fn default() -> Self {
        Self {
            split_ratio: 0.5,
            merge_ratio: 2.0,
            min_part: 2,
            max_pids: 64,
        }
    }
}

/// Stateless controller: feed it the observed per-PID update counts since
/// the last decision plus the current partition; get an action.
#[derive(Clone, Debug, Default)]
pub struct AdaptiveController {
    pub policy: AdaptivePolicy,
}


impl AdaptiveController {
    pub fn new(policy: AdaptivePolicy) -> Self {
        Self { policy }
    }

    /// Decide based on per-PID update counts over the same wall interval.
    /// Rates are normalized *per owned coordinate* so a PID with a bigger
    /// Ω_k is not mistaken for a fast one.
    pub fn decide(&self, partition: &Partition, updates: &[u64]) -> Adaptation {
        let k = partition.k();
        assert_eq!(updates.len(), k, "one update count per PID");
        if k < 2 {
            return Adaptation::Keep;
        }
        let rates: Vec<f64> = (0..k)
            .map(|p| updates[p] as f64 / partition.part(p).len().max(1) as f64)
            .collect();
        let mut sorted = rates.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = sorted[k / 2];
        if median <= 0.0 {
            return Adaptation::Keep; // no signal yet
        }
        // straggler? split it (if splittable and we have PID headroom)
        let (slowest, &slow_rate) = rates
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap();
        if slow_rate < self.policy.split_ratio * median
            && partition.part(slowest).len() >= 2 * self.policy.min_part
            && k < self.policy.max_pids
        {
            return Adaptation::Split { pid: slowest };
        }
        // two clear over-performers? merge them
        let mut by_rate: Vec<usize> = (0..k).collect();
        by_rate.sort_by(|&a, &b| rates[b].partial_cmp(&rates[a]).unwrap());
        let (fa, fb) = (by_rate[0], by_rate[1]);
        if k > 2
            && rates[fa] > self.policy.merge_ratio * median
            && rates[fb] > self.policy.merge_ratio * median
        {
            return Adaptation::Merge {
                fast_a: fa.min(fb),
                fast_b: fa.max(fb),
            };
        }
        Adaptation::Keep
    }

    /// Apply a decision, returning the (validated) new partition.
    pub fn apply(
        &self,
        partition: &Partition,
        action: &Adaptation,
    ) -> crate::error::Result<Partition> {
        let next = match action {
            Adaptation::Keep => partition.clone(),
            Adaptation::Split { pid } => partition.split_part(*pid)?,
            Adaptation::Merge { fast_a, fast_b } => partition.merge_parts(*fast_a, *fast_b)?,
        };
        next.validate()?;
        Ok(next)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctl() -> AdaptiveController {
        AdaptiveController::new(AdaptivePolicy::default())
    }

    #[test]
    fn balanced_rates_keep() {
        let p = Partition::contiguous(40, 4).unwrap();
        let a = ctl().decide(&p, &[100, 110, 95, 105]);
        assert_eq!(a, Adaptation::Keep);
    }

    #[test]
    fn straggler_triggers_split() {
        let p = Partition::contiguous(40, 4).unwrap();
        // PID 2 at 20% of the others' rate
        let a = ctl().decide(&p, &[100, 100, 20, 100]);
        assert_eq!(a, Adaptation::Split { pid: 2 });
        let next = ctl().apply(&p, &a).unwrap();
        assert_eq!(next.k(), 5);
        next.validate().unwrap();
    }

    #[test]
    fn split_respects_min_part() {
        let policy = AdaptivePolicy {
            min_part: 10,
            ..Default::default()
        };
        let c = AdaptiveController::new(policy);
        let p = Partition::contiguous(40, 4).unwrap(); // parts of 10 < 2*min
        let a = c.decide(&p, &[100, 100, 10, 100]);
        assert_eq!(a, Adaptation::Keep);
    }

    #[test]
    fn rates_normalized_per_coordinate() {
        // PID 0 owns 30 coords, PIDs 1-2 own 5 each; equal *total* updates
        // mean PID 0 is actually the straggler per coordinate — but at
        // 1/6 ratio ≈ 0.33 < 0.5 of median it must be the split target
        let owner: Vec<usize> = (0..40)
            .map(|i| if i < 30 { 0 } else if i < 35 { 1 } else { 2 })
            .collect();
        let p = Partition::from_owner(owner, 3).unwrap();
        let a = ctl().decide(&p, &[100, 100, 100]);
        assert_eq!(a, Adaptation::Split { pid: 0 });
    }

    #[test]
    fn two_fast_pids_merge() {
        let p = Partition::contiguous(40, 5).unwrap();
        // two PIDs far above the (upper) median, none below half of it:
        // rates [62.5, 62.5, 12.5, 12.5, 11.25], median 12.5 — the slowest
        // (11.25) clears the 0.5 split ratio, the two fastest clear 2×
        let a = ctl().decide(&p, &[500, 500, 100, 100, 90]);
        match a {
            Adaptation::Merge { fast_a, fast_b } => {
                assert_eq!((fast_a, fast_b), (0, 1));
            }
            other => panic!("expected merge, got {other:?}"),
        }
        let next = ctl().apply(&p, &a).unwrap();
        assert_eq!(next.k(), 4);
    }

    #[test]
    fn no_signal_keeps() {
        let p = Partition::contiguous(8, 2).unwrap();
        assert_eq!(ctl().decide(&p, &[0, 0]), Adaptation::Keep);
    }

    #[test]
    fn single_pid_keeps() {
        let p = Partition::contiguous(8, 1).unwrap();
        assert_eq!(ctl().decide(&p, &[100]), Adaptation::Keep);
    }

    #[test]
    fn max_pids_cap() {
        let policy = AdaptivePolicy {
            max_pids: 4,
            ..Default::default()
        };
        let c = AdaptiveController::new(policy);
        let p = Partition::contiguous(40, 4).unwrap();
        let a = c.decide(&p, &[100, 100, 10, 100]);
        assert_eq!(a, Adaptation::Keep, "at the PID cap, no split");
    }
}
