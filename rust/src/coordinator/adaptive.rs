//! §4.3 speed adaptation: "when the PIDs advance at very different speeds
//! (monitoring T_k), we can think of splitting the set Ω_k associated to
//! the slowest PID_k or possibly regrouping Ω_k associated to the fastest
//! PID_k".
//!
//! [`AdaptiveController`] watches per-PID progress (scalar updates per
//! wall second, as published through [`super::monitor::MonitorState`]) and
//! recommends repartitioning actions. The mechanics (exact-cover-preserving
//! [`Partition::split_part`] / [`Partition::merge_parts`] /
//! [`Partition::transfer`]) live in the partition module; this controller
//! supplies the *policy*.
//!
//! Two policy surfaces:
//!
//! * [`AdaptiveController::decide`] — the paper's elastic form: grow or
//!   shrink the PID count (split the straggler's Ω, merge the two fastest)
//!   for deployments that can spawn/retire workers between runs.
//! * [`AdaptiveController::plan_rebalance`] — the **live** form used by
//!   the running engines: on a fixed worker pool, "splitting the slowest
//!   PID's Ω_k" means offloading half of it to the fastest PID. The plan
//!   is installed into the [`crate::partition::OwnershipTable`] and the
//!   workers ship the `(H, B, F)` slices themselves (see
//!   [`super::worker`]).

use std::time::{Duration, Instant};

use crate::metrics::MetricSet;
use crate::partition::{OwnershipTable, Partition};
use crate::sparse::SparseMatrix;

/// A recommended repartitioning action.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Adaptation {
    /// everything within tolerance: keep the current partition
    Keep,
    /// split the slowest PID's set (it is the straggler)
    Split { pid: usize },
    /// merge the two fastest PIDs' sets (they idle waiting for stragglers)
    Merge { fast_a: usize, fast_b: usize },
}

/// Policy knobs.
#[derive(Clone, Copy, Debug)]
pub struct AdaptivePolicy {
    /// recommend a split when the slowest PID's *per-coordinate* rate is
    /// below `split_ratio` × the median rate (straggler detection)
    pub split_ratio: f64,
    /// recommend a merge when the two fastest PIDs are each above
    /// `merge_ratio` × the median rate
    pub merge_ratio: f64,
    /// never shrink a part below this many coordinates by splitting
    pub min_part: usize,
    /// never grow the PID count beyond this
    pub max_pids: usize,
}

impl Default for AdaptivePolicy {
    fn default() -> Self {
        Self {
            split_ratio: 0.5,
            merge_ratio: 2.0,
            min_part: 2,
            max_pids: 64,
        }
    }
}

/// Stateless controller: feed it the observed per-PID update counts since
/// the last decision plus the current partition; get an action.
#[derive(Clone, Debug, Default)]
pub struct AdaptiveController {
    pub policy: AdaptivePolicy,
}

impl AdaptiveController {
    pub fn new(policy: AdaptivePolicy) -> Self {
        Self { policy }
    }

    /// Decide based on per-PID update counts over the same wall interval.
    /// Rates are normalized *per owned coordinate* so a PID with a bigger
    /// Ω_k is not mistaken for a fast one.
    pub fn decide(&self, partition: &Partition, updates: &[u64]) -> Adaptation {
        let k = partition.k();
        assert_eq!(updates.len(), k, "one update count per PID");
        if k < 2 {
            return Adaptation::Keep;
        }
        let (rates, median) = per_coord_rates(partition, updates);
        if median <= 0.0 {
            return Adaptation::Keep; // no signal yet
        }
        // straggler? split it (if splittable and we have PID headroom)
        if k < self.policy.max_pids {
            if let Some(pid) = self.straggler(partition, &rates, median) {
                return Adaptation::Split { pid };
            }
        }
        // two clear over-performers? merge them
        let mut by_rate: Vec<usize> = (0..k).collect();
        by_rate.sort_by(|&a, &b| rates[b].partial_cmp(&rates[a]).unwrap());
        let (fa, fb) = (by_rate[0], by_rate[1]);
        if k > 2
            && rates[fa] > self.policy.merge_ratio * median
            && rates[fb] > self.policy.merge_ratio * median
        {
            return Adaptation::Merge {
                fast_a: fa.min(fb),
                fast_b: fa.max(fb),
            };
        }
        Adaptation::Keep
    }

    /// The straggler criterion shared by both policy surfaces: the
    /// lowest-rate PID, provided it is below `split_ratio` × median and
    /// its Ω is big enough to shed half.
    fn straggler(&self, partition: &Partition, rates: &[f64], median: f64) -> Option<usize> {
        let (slowest, &slow_rate) = rates
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())?;
        if slow_rate < self.policy.split_ratio * median
            && partition.part(slowest).len() >= 2 * self.policy.min_part
        {
            Some(slowest)
        } else {
            None
        }
    }

    /// Apply a decision, returning the (validated) new partition.
    pub fn apply(
        &self,
        partition: &Partition,
        action: &Adaptation,
    ) -> crate::error::Result<Partition> {
        let next = match action {
            Adaptation::Keep => partition.clone(),
            Adaptation::Split { pid } => partition.split_part(*pid)?,
            Adaptation::Merge { fast_a, fast_b } => partition.merge_parts(*fast_a, *fast_b)?,
        };
        next.validate()?;
        Ok(next)
    }

    /// The fixed-pool form of §4.3: if one PID's per-coordinate rate fell
    /// below `split_ratio` × median over the observation window AND it
    /// still holds fluid, move half of its Ω to the fastest PID.
    /// `updates` are the per-PID scalar-update counts over the window;
    /// `backlog` is each PID's published remaining fluid — a drained PID
    /// updates nothing because it is *idle*, not slow, and must never be
    /// mistaken for a straggler.
    ///
    /// **Cut-aware half selection**: with the iteration matrix available,
    /// the shed half (upper or lower) is the one whose transfer minimizes
    /// the resulting edge cut (the [`Partition::cut_fraction`] criterion)
    /// — a smaller cut is directly a smaller cross-part remnant for the
    /// workers' local-block kernel to flush after the move. The candidates
    /// are scored as cut *deltas* over only the edges incident to each
    /// moved set (O(deg) per candidate, not O(nnz) — this runs on the
    /// monitor thread). Without a matrix the upper half is moved (the
    /// pre-cut-aware behaviour).
    pub fn plan_rebalance(
        &self,
        partition: &Partition,
        updates: &[u64],
        backlog: &[f64],
        matrix: Option<&SparseMatrix>,
    ) -> Option<HandoffPlan> {
        let k = partition.k();
        assert_eq!(updates.len(), k, "one update count per PID");
        assert_eq!(backlog.len(), k, "one backlog reading per PID");
        if k < 2 {
            return None;
        }
        let (rates, median) = per_coord_rates(partition, updates);
        if median <= 0.0 {
            return None; // no signal yet
        }
        let slowest = self.straggler(partition, &rates, median)?;
        if backlog[slowest] <= 0.0 {
            return None; // fluid-starved, not struggling
        }
        let (fastest, _) = rates
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())?;
        if fastest == slowest {
            return None;
        }
        let coords = choose_shed_half(partition, slowest, fastest, matrix);
        Some(HandoffPlan {
            from: slowest,
            to: fastest,
            coords,
        })
    }
}

/// Which half of `from`'s Ω should move to `to`: the cut-aware selection
/// shared by [`AdaptiveController::plan_rebalance`] (fixed-pool shed) and
/// the elastic pool's spawn-split (`to` is then a freshly-grown, still
/// empty part). With a matrix, the half whose transfer minimizes the
/// resulting edge cut (scored via [`cut_delta`]); without, the upper half.
pub(crate) fn choose_shed_half(
    partition: &Partition,
    from: usize,
    to: usize,
    matrix: Option<&SparseMatrix>,
) -> Vec<usize> {
    let members = partition.part(from);
    let shed = members.len() - members.len() / 2;
    let upper = &members[members.len() / 2..];
    let lower = &members[..shed];
    match matrix {
        None => upper.to_vec(),
        Some(p) => {
            let dl = cut_delta(p, partition, lower, to);
            let du = cut_delta(p, partition, upper, to);
            if dl < du {
                lower.to_vec()
            } else {
                upper.to_vec() // tie: upper (the pre-cut-aware pick)
            }
        }
    }
}

/// Change in total cut weight if the (sorted) coordinate set `cand` moved
/// to part `to`: only edges incident to `cand` can change crossing state,
/// so the scan is O(Σ deg(cand)) via the CSR rows (out-edges) and CSC
/// columns (in-edges) — never the whole matrix. Comparing deltas orders
/// candidates exactly like comparing full [`Partition::cut_fraction`]s
/// (the common baseline cancels).
fn cut_delta(matrix: &SparseMatrix, partition: &Partition, cand: &[usize], to: usize) -> f64 {
    debug_assert!(cand.windows(2).all(|w| w[0] <= w[1]), "cand must be sorted");
    let moved = |x: usize| cand.binary_search(&x).is_ok();
    let mut delta = 0.0;
    for &i in cand {
        // out-edges (i → j), including those whose far end also moves
        let (cols, vals) = matrix.csr().row(i);
        for e in 0..cols.len() {
            let j = cols[e];
            let w = vals[e].abs();
            let before = partition.owner(i) != partition.owner(j);
            let after = to != if moved(j) { to } else { partition.owner(j) };
            delta += (i32::from(after) - i32::from(before)) as f64 * w;
        }
        // in-edges (s → i) from coordinates staying put (moved sources
        // were already counted by their own row scan above)
        let (srcs, svals) = matrix.csc().col(i);
        for e in 0..srcs.len() {
            let s = srcs[e];
            if moved(s) {
                continue;
            }
            let w = svals[e].abs();
            let before = partition.owner(s) != partition.owner(i);
            let after = partition.owner(s) != to;
            delta += (i32::from(after) - i32::from(before)) as f64 * w;
        }
    }
    delta
}

/// Per-coordinate update rates and their median (the shared normalization
/// of both [`AdaptiveController::decide`] and
/// [`AdaptiveController::plan_rebalance`]).
fn per_coord_rates(partition: &Partition, updates: &[u64]) -> (Vec<f64>, f64) {
    let k = partition.k();
    let rates: Vec<f64> = (0..k)
        .map(|p| updates[p] as f64 / partition.part(p).len().max(1) as f64)
        .collect();
    let mut sorted = rates.clone();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    (rates, sorted[k / 2])
}

/// A concrete coordinate move on a fixed worker pool.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct HandoffPlan {
    /// straggling PID shedding load
    pub from: usize,
    /// fastest PID absorbing it
    pub to: usize,
    /// the coordinates to move (half of `from`'s Ω)
    pub coords: Vec<usize>,
}

/// Knobs for live adaptation inside a running engine.
#[derive(Clone, Debug)]
pub struct AdaptiveConfig {
    pub policy: AdaptivePolicy,
    /// minimum wall time between rebalance decisions (the observation
    /// window over which per-PID rates are measured)
    pub interval: Duration,
    /// hard cap on ownership moves per run (runaway guard)
    pub max_moves: u64,
}

impl Default for AdaptiveConfig {
    fn default() -> Self {
        Self {
            policy: AdaptivePolicy::default(),
            interval: Duration::from_millis(40),
            max_moves: 1000,
        }
    }
}

/// Leader-side driver: windows the per-PID update counters, asks the
/// controller for a plan, and installs it into the ownership table. Used
/// by both `solve_v2`'s monitor loop and `StreamingEngine::converge`.
pub struct AdaptiveDriver {
    ctl: AdaptiveController,
    interval: Duration,
    max_moves: u64,
    /// below this much total fluid the run is nearly drained — migrating
    /// then buys nothing and only races the shutdown
    min_total: f64,
    last_decision: Instant,
    last_counts: Vec<u64>,
    moves: u64,
}

impl AdaptiveDriver {
    pub fn new(cfg: &AdaptiveConfig, k: usize, tol: f64) -> AdaptiveDriver {
        AdaptiveDriver {
            ctl: AdaptiveController::new(cfg.policy),
            interval: cfg.interval,
            max_moves: cfg.max_moves,
            min_total: tol * 100.0,
            last_decision: Instant::now(),
            last_counts: vec![0; k],
            moves: 0,
        }
    }

    /// Ownership moves installed so far.
    pub fn moves(&self) -> u64 {
        self.moves
    }

    /// Poll with the current cumulative per-PID update counts, per-PID
    /// published fluid backlog, and the monitored total fluid; installs at
    /// most one rebalance per elapsed interval. `matrix` (when available)
    /// makes the half selection cut-aware. Returns whether a new
    /// ownership map was installed.
    pub fn poll(
        &mut self,
        table: &OwnershipTable,
        counts: &[u64],
        backlog: &[f64],
        total: f64,
        metrics: &MetricSet,
        matrix: Option<&SparseMatrix>,
    ) -> bool {
        if !total.is_finite() || total <= self.min_total {
            return false; // not every PID published yet, or nearly drained
        }
        if self.last_decision.elapsed() < self.interval || self.moves >= self.max_moves {
            return false;
        }
        if !table.all_acked(table.version()) || table.handoffs_inflight() > 0 {
            return false; // let the previous move land before measuring
        }
        let deltas: Vec<u64> = counts
            .iter()
            .zip(&self.last_counts)
            .map(|(now, base)| now.saturating_sub(*base))
            .collect();
        self.last_counts = counts.to_vec();
        self.last_decision = Instant::now();
        let part = table.partition();
        let Some(plan) = self.ctl.plan_rebalance(&part, &deltas, backlog, matrix) else {
            return false;
        };
        let Ok(next) = part.transfer(&plan.coords, plan.to) else {
            return false;
        };
        if table.install(next).is_none() {
            return false; // frozen (epoch transition in progress)
        }
        self.moves += 1;
        metrics.set("handoffs_planned", self.moves);
        metrics.set(
            "load_imbalance_ppm",
            (table.partition().imbalance() * 1e6) as u64,
        );
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctl() -> AdaptiveController {
        AdaptiveController::new(AdaptivePolicy::default())
    }

    #[test]
    fn balanced_rates_keep() {
        let p = Partition::contiguous(40, 4).unwrap();
        let a = ctl().decide(&p, &[100, 110, 95, 105]);
        assert_eq!(a, Adaptation::Keep);
    }

    #[test]
    fn straggler_triggers_split() {
        let p = Partition::contiguous(40, 4).unwrap();
        // PID 2 at 20% of the others' rate
        let a = ctl().decide(&p, &[100, 100, 20, 100]);
        assert_eq!(a, Adaptation::Split { pid: 2 });
        let next = ctl().apply(&p, &a).unwrap();
        assert_eq!(next.k(), 5);
        next.validate().unwrap();
    }

    #[test]
    fn split_respects_min_part() {
        let policy = AdaptivePolicy {
            min_part: 10,
            ..Default::default()
        };
        let c = AdaptiveController::new(policy);
        let p = Partition::contiguous(40, 4).unwrap(); // parts of 10 < 2*min
        let a = c.decide(&p, &[100, 100, 10, 100]);
        assert_eq!(a, Adaptation::Keep);
    }

    #[test]
    fn rates_normalized_per_coordinate() {
        // PID 0 owns 30 coords, PIDs 1-2 own 5 each; equal *total* updates
        // mean PID 0 is actually the straggler per coordinate — but at
        // 1/6 ratio ≈ 0.33 < 0.5 of median it must be the split target
        let owner: Vec<usize> = (0..40)
            .map(|i| if i < 30 { 0 } else if i < 35 { 1 } else { 2 })
            .collect();
        let p = Partition::from_owner(owner, 3).unwrap();
        let a = ctl().decide(&p, &[100, 100, 100]);
        assert_eq!(a, Adaptation::Split { pid: 0 });
    }

    #[test]
    fn two_fast_pids_merge() {
        let p = Partition::contiguous(40, 5).unwrap();
        // two PIDs far above the (upper) median, none below half of it:
        // rates [62.5, 62.5, 12.5, 12.5, 11.25], median 12.5 — the slowest
        // (11.25) clears the 0.5 split ratio, the two fastest clear 2×
        let a = ctl().decide(&p, &[500, 500, 100, 100, 90]);
        match a {
            Adaptation::Merge { fast_a, fast_b } => {
                assert_eq!((fast_a, fast_b), (0, 1));
            }
            other => panic!("expected merge, got {other:?}"),
        }
        let next = ctl().apply(&p, &a).unwrap();
        assert_eq!(next.k(), 4);
    }

    #[test]
    fn no_signal_keeps() {
        let p = Partition::contiguous(8, 2).unwrap();
        assert_eq!(ctl().decide(&p, &[0, 0]), Adaptation::Keep);
    }

    #[test]
    fn single_pid_keeps() {
        let p = Partition::contiguous(8, 1).unwrap();
        assert_eq!(ctl().decide(&p, &[100]), Adaptation::Keep);
    }

    #[test]
    fn max_pids_cap() {
        let policy = AdaptivePolicy {
            max_pids: 4,
            ..Default::default()
        };
        let c = AdaptiveController::new(policy);
        let p = Partition::contiguous(40, 4).unwrap();
        let a = c.decide(&p, &[100, 100, 10, 100]);
        assert_eq!(a, Adaptation::Keep, "at the PID cap, no split");
    }

    #[test]
    fn rebalance_moves_half_of_straggler_to_fastest() {
        let p = Partition::contiguous(40, 4).unwrap();
        let backlog = [1.0; 4];
        let plan = ctl()
            .plan_rebalance(&p, &[100, 180, 20, 100], &backlog, None)
            .unwrap();
        assert_eq!(plan.from, 2);
        assert_eq!(plan.to, 1);
        assert_eq!(plan.coords, p.part(2)[5..].to_vec(), "upper half of Ω_2");
        let next = p.transfer(&plan.coords, plan.to).unwrap();
        next.validate().unwrap();
        assert_eq!(next.part_sizes(), vec![10, 15, 5, 10]);
    }

    #[test]
    fn rebalance_is_cut_aware_with_a_matrix() {
        use crate::sparse::TripletBuilder;
        // 12 coordinates, 3 contiguous parts of 4. The straggler is part
        // 0; the fastest is part 2. Coordinates {0, 1} (the LOWER half of
        // Ω_0) are strongly coupled to part 2's range, {2, 3} to part 1 —
        // shedding the lower half to part 2 shrinks the cut, shedding the
        // upper half grows it.
        let mut b = TripletBuilder::new(12, 12);
        for &i in &[0usize, 1] {
            for j in 8..12 {
                b.push(i, j, 0.2);
                b.push(j, i, 0.2);
            }
        }
        for &i in &[2usize, 3] {
            for j in 4..8 {
                b.push(i, j, 0.2);
                b.push(j, i, 0.2);
            }
        }
        let m = SparseMatrix::from_csr(b.to_csr());
        let p = Partition::contiguous(12, 3).unwrap();
        let backlog = [1.0; 3];
        let updates = [10, 100, 200]; // straggler 0, fastest 2
        let aware = ctl()
            .plan_rebalance(&p, &updates, &backlog, Some(&m))
            .unwrap();
        assert_eq!((aware.from, aware.to), (0, 2));
        assert_eq!(aware.coords, vec![0, 1], "lower half cuts less");
        let blind = ctl()
            .plan_rebalance(&p, &updates, &backlog, None)
            .unwrap();
        assert_eq!(blind.coords, vec![2, 3], "matrix-blind default: upper");
        // and the chosen half really does yield the smaller cut
        let cut_aware = p.transfer(&aware.coords, 2).unwrap().cut_fraction(m.csr());
        let cut_blind = p.transfer(&blind.coords, 2).unwrap().cut_fraction(m.csr());
        assert!(cut_aware < cut_blind, "{cut_aware} !< {cut_blind}");
    }

    #[test]
    fn cut_delta_orders_candidates_like_full_cut_fraction() {
        use crate::prop::run_cases;
        // the O(deg) incremental score must induce the same ordering as
        // rebuilding the partition and rescanning the whole matrix
        run_cases(25, 0xC07DE17A, |g| {
            let n = g.usize_in(9, 30);
            let m = SparseMatrix::from_csr(g.contraction_matrix(n, 3, 0.9));
            let k = 3;
            let p = Partition::contiguous(n, k).unwrap();
            let from = g.usize_in(0, k - 1);
            let to = (from + 1 + g.usize_in(0, k - 2)) % k;
            let members = p.part(from);
            if members.len() < 3 {
                return;
            }
            let shed = members.len() - members.len() / 2;
            for cand in [&members[members.len() / 2..], &members[..shed]] {
                let full = p.transfer(cand, to).unwrap().cut_fraction(m.csr());
                let base = p.cut_fraction(m.csr());
                let total: f64 = m.csr().row_l1_norms().iter().sum();
                let delta = cut_delta(&m, &p, cand, to);
                assert!(
                    (full - (base + delta / total)).abs() < 1e-9,
                    "delta {delta} disagrees with full rescan ({base} -> {full})"
                );
            }
        });
    }

    #[test]
    fn rebalance_keeps_when_balanced_tiny_or_drained() {
        let p = Partition::contiguous(40, 4).unwrap();
        let backlog = [1.0; 4];
        assert!(ctl()
            .plan_rebalance(&p, &[100, 110, 95, 105], &backlog, None)
            .is_none());
        assert!(ctl()
            .plan_rebalance(&p, &[0, 0, 0, 0], &backlog, None)
            .is_none());
        // a low-rate PID with NO fluid is idle, not slow — never offloaded
        assert!(ctl()
            .plan_rebalance(&p, &[100, 100, 0, 100], &[1.0, 1.0, 0.0, 1.0], None)
            .is_none());
        let policy = AdaptivePolicy {
            min_part: 10,
            ..Default::default()
        };
        let c = AdaptiveController::new(policy);
        // straggler's part (10) is below 2×min_part: nothing to shed
        assert!(c
            .plan_rebalance(&p, &[100, 100, 10, 100], &backlog, None)
            .is_none());
    }

    #[test]
    fn driver_installs_on_straggler_trace() {
        use crate::metrics::MetricSet;
        use crate::partition::OwnershipTable;
        let table = OwnershipTable::new(Partition::contiguous(40, 4).unwrap());
        let metrics = MetricSet::new(&["handoffs_planned", "load_imbalance_ppm"]);
        let cfg = AdaptiveConfig {
            interval: Duration::from_millis(0),
            ..Default::default()
        };
        let mut driver = AdaptiveDriver::new(&cfg, 4, 1e-9);
        let backlog = [0.5; 4];
        // synthetic straggler trace: PID 2 at 20% of the others
        assert!(driver.poll(&table, &[100, 100, 20, 100], &backlog, 2.0, &metrics, None));
        assert_eq!(driver.moves(), 1);
        assert_eq!(table.version(), 1);
        assert!(table.partition().part(2).len() < 10);
        assert!(metrics.get("load_imbalance_ppm") > 1_000_000);
        // nearly-drained run: no further migration
        assert!(!driver.poll(&table, &[200, 200, 40, 200], &backlog, 1e-8, &metrics, None));
        // frozen table: decision is a no-op (workers synced ⇒ acked)
        table.ack_version(0, 1);
        table.ack_version(1, 1);
        table.ack_version(2, 1);
        table.ack_version(3, 1);
        table.freeze();
        assert!(!driver.poll(&table, &[300, 300, 60, 300], &backlog, 2.0, &metrics, None));
        assert_eq!(driver.moves(), 1);
    }
}
