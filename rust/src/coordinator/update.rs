//! §3.2 — live evolution of P: rebase the running computation onto a new
//! matrix P' without restarting and without global synchronization.
//!
//! If H is the history accumulated so far under (P, B), the remaining work
//! for the *new* system `X' = P'·X' + B` is the fixed point of
//!
//! ```text
//! Y = P'·Y + B'   with   B' = F + (P'−P)·H = P'·H + B − H
//! ```
//!
//! and `X' = H + Y`. Each PID can compute its own slice of B' locally from
//! its rows of P' (the middle expression is the paper's; the right-hand
//! form shows only P' is actually needed). This is Theorem 4 of [4]
//! operationalized.
//!
//! For the **V1 / H-form** scheme there is an even simpler equivalent: the
//! in-place update `H_i ← L_i(P')·H + B_i` converges to X' from *any*
//! starting point, so switching the matrix and keeping H warm is already
//! correct; [`rebase_b`] is what the **fluid form (V2)** needs, where F
//! must be reset to the consistent `F'₀ = B'`.

use crate::error::{DiterError, Result};
use crate::sparse::{CscMatrix, SparseMatrix};

/// Compute the rebased offset `B' = P'·H + B − H` (all coordinates).
pub fn rebase_b(p_new: &SparseMatrix, h: &[f64], b: &[f64]) -> Result<Vec<f64>> {
    if h.len() != p_new.n() || b.len() != p_new.n() {
        return Err(DiterError::shape("rebase_b", p_new.n(), h.len()));
    }
    let mut out = p_new.csr().matvec(h)?;
    for i in 0..out.len() {
        out[i] += b[i] - h[i];
    }
    Ok(out)
}

/// Compute only the owned slice of B' (what one PID does locally):
/// `B'_i = L_i(P')·H + B_i − H_i` for `i ∈ owned`.
pub fn rebase_b_slice(
    p_new: &SparseMatrix,
    owned: &[usize],
    h: &[f64],
    b: &[f64],
) -> Vec<f64> {
    let csr = p_new.csr();
    owned
        .iter()
        .map(|&i| csr.row_dot(i, h) + b[i] - h[i])
        .collect()
}

/// Reconstruct the fluid a crashed worker lost: `F_i = B_i − ((I−P)·H)_i
/// = L_i(P)·H + B_i − H_i` for `i ∈ owned` — [`rebase_b_slice`] with
/// P' = P (eq. 4 rearranged: when the matrix does not change, B' *is*
/// the current fluid). Conservation makes this exact for **any** H: the
/// run's invariant is `F = B + (P−I)·H` globally at every instant, with
/// in-flight parcels counted in F — so recomputing F from whatever H
/// survives (a checkpoint, or zero for coordinates never snapshotted)
/// rewinds progress on the crashed slice without ever moving the fixed
/// point. Recovery pairs this with an epoch bump so the dead worker's
/// in-flight parcels are discarded (and their mass committed) on
/// arrival instead of double-counting against the reconstruction.
pub fn reconstruct_f_slice(
    p: &SparseMatrix,
    owned: &[usize],
    h: &[f64],
    b: &[f64],
) -> Vec<f64> {
    rebase_b_slice(p, owned, h, b)
}

/// The §3.1 (V1, full/halo history) **local** rebase: patch one PID's
/// fluid slice in place with the delta form `F' = F + (P' − P)·H`,
/// reading only the columns that actually changed — everywhere else
/// P' = P and the delta vanishes, which is why only the dirty columns'
/// H values ever cross the wire.
///
/// `halo` carries `(u, H_u)` for every dirty column: the owner's own
/// snapshot, or the value a peer shipped in a
/// [`super::worker::WorkerMsg::HaloSlice`]. Each H_u must be the value
/// at that column's switch instant (its owner freezes diffusion of `u`
/// from the snapshot until its own epoch entry), which makes the delayed
/// per-owner application exact — see DESIGN.md §7 for the argument.
///
/// Rows not owned here (`local_of[j] == usize::MAX`) are skipped; their
/// owners apply the same contribution from their own halo view, so the
/// per-PID applications concatenate to the full `(P'−P)·H` exactly once
/// per coordinate. Returns the touched local slots (duplicates possible)
/// so the caller can requeue them in its diffusion order.
pub fn rebase_b_slice_local(
    p_old: &CscMatrix,
    p_new: &CscMatrix,
    halo: &[(usize, f64)],
    local_of: &[usize],
    f: &mut [f64],
) -> Vec<usize> {
    rebase_b_slice_local_lane(p_old, p_new, halo, local_of, f, 1, 0)
}

/// Lane-addressed form of [`rebase_b_slice_local`] for the multi-RHS
/// serving layer (DESIGN.md §10): `f` is lane-blocked (slot-major,
/// `lanes` cells per slot) and the delta for this lane's history lands in
/// `f[t * lanes + lane]`. D-iteration is linear in B, so each lane
/// rebases independently from its own `(u, H_u)` halo; a query's seed
/// RHS lives in the registry and never enters the delta. Returns touched
/// local **slots** (not flat cells), duplicates possible.
pub fn rebase_b_slice_local_lane(
    p_old: &CscMatrix,
    p_new: &CscMatrix,
    halo: &[(usize, f64)],
    local_of: &[usize],
    f: &mut [f64],
    lanes: usize,
    lane: usize,
) -> Vec<usize> {
    debug_assert!(lane < lanes);
    let mut touched = Vec::new();
    for &(u, hu) in halo {
        if hu == 0.0 {
            continue; // a never-diffused column contributes nothing
        }
        let (rows, vals) = p_old.col(u);
        for e in 0..rows.len() {
            let t = local_of[rows[e]];
            if t != usize::MAX {
                f[t * lanes + lane] -= vals[e] * hu;
                touched.push(t);
            }
        }
        let (rows, vals) = p_new.col(u);
        for e in 0..rows.len() {
            let t = local_of[rows[e]];
            if t != usize::MAX {
                f[t * lanes + lane] += vals[e] * hu;
                touched.push(t);
            }
        }
    }
    touched
}

/// The dirty-column set two matrices disagree on (ascending): the inputs
/// tests and callers without a [`crate::graph::MutableDigraph`] build
/// report feed into [`rebase_b_slice_local`].
pub fn differing_columns(a: &CscMatrix, b: &CscMatrix) -> Vec<usize> {
    debug_assert_eq!(a.ncols(), b.ncols());
    (0..a.ncols()).filter(|&u| a.col(u) != b.col(u)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::paper_matrix;
    use crate::linalg::vec_ops::dist_inf;
    use crate::solver::{DIteration, FixedPointProblem, SolveOptions, Solver};

    /// The §5.2 scenario: run on P (from A), partially converge, switch to
    /// P' (from A'), rebase, finish — the result must equal the cold-start
    /// solution of the new system.
    #[test]
    fn rebase_reaches_new_limit() {
        let p_old = FixedPointProblem::from_linear_system(&paper_matrix(1), &[1.0; 4]).unwrap();
        let p_new = FixedPointProblem::from_linear_system(&paper_matrix(4), &[1.0; 4]).unwrap();
        // partial run on the old system
        let opts = SolveOptions {
            tol: 0.0,
            max_cost: 5.0,
            trace_every: 0.0,
            exact: None,
        };
        let partial = DIteration::cyclic().solve(&p_old, &opts).unwrap();
        let h = partial.x.clone();
        // rebase: Y = P'Y + B' ; X' = H + Y
        let b_prime = rebase_b(p_new.matrix(), &h, p_new.b()).unwrap();
        let sub = FixedPointProblem::new(p_new.matrix().clone(), b_prime).unwrap();
        let y = DIteration::cyclic()
            .solve(&sub, &SolveOptions::default())
            .unwrap();
        let x: Vec<f64> = h.iter().zip(&y.x).map(|(a, b)| a + b).collect();
        let exact = p_new.exact_solution().unwrap();
        assert!(dist_inf(&x, &exact) < 1e-9, "dist {}", dist_inf(&x, &exact));
    }

    #[test]
    fn slice_matches_full() {
        let p_new = FixedPointProblem::from_linear_system(&paper_matrix(4), &[1.0; 4]).unwrap();
        let h = vec![0.1, 0.2, 0.3, 0.4];
        let full = rebase_b(p_new.matrix(), &h, p_new.b()).unwrap();
        let slice = rebase_b_slice(p_new.matrix(), &[1, 3], &h, p_new.b());
        assert_eq!(slice, vec![full[1], full[3]]);
    }

    #[test]
    fn identity_update_is_plain_fluid() {
        // P' = P ⇒ B' = F (the current fluid) — eq. 4 rearranged
        let p = FixedPointProblem::from_linear_system(&paper_matrix(2), &[1.0; 4]).unwrap();
        let h = vec![0.05, 0.1, 0.15, 0.2];
        let b_prime = rebase_b(p.matrix(), &h, p.b()).unwrap();
        let f = p.fluid(&h);
        for i in 0..4 {
            assert!((b_prime[i] - f[i]).abs() < 1e-15);
        }
    }

    /// `reconstruct_f_slice` must agree with the consistent fluid of the
    /// running system restricted to any owned set — including H = 0
    /// (recovery with no checkpoint: F rewinds all the way to B).
    #[test]
    fn reconstruct_matches_consistent_fluid() {
        let p = FixedPointProblem::from_linear_system(&paper_matrix(2), &[1.0; 4]).unwrap();
        let h = vec![0.12, 0.0, 0.31, 0.27];
        let full_f = p.fluid(&h);
        for owned in [vec![0usize, 1], vec![2, 3], vec![1, 3], vec![0, 1, 2, 3]] {
            let f = reconstruct_f_slice(p.matrix(), &owned, &h, p.b());
            for (t, &i) in owned.iter().enumerate() {
                assert!(
                    (f[t] - full_f[i]).abs() < 1e-15,
                    "coord {i}: {} vs {}",
                    f[t],
                    full_f[i]
                );
            }
        }
        let cold = reconstruct_f_slice(p.matrix(), &[0, 1, 2, 3], &[0.0; 4], p.b());
        assert_eq!(cold, p.b().to_vec(), "zero history reconstructs F = B");
    }

    #[test]
    fn shape_errors() {
        let p = FixedPointProblem::from_linear_system(&paper_matrix(1), &[1.0; 4]).unwrap();
        assert!(rebase_b(p.matrix(), &[0.0; 3], p.b()).is_err());
    }

    /// The V1 delta form over dirty columns must agree with the leader's
    /// `B'` slice: `F + (P'−P)·H ≡ P'·H + B − H` restricted to any owned
    /// set, when F is the consistent fluid of the old system.
    #[test]
    fn local_delta_matches_leader_slice() {
        let p_old = FixedPointProblem::from_linear_system(&paper_matrix(1), &[1.0; 4]).unwrap();
        let p_new = FixedPointProblem::from_linear_system(&paper_matrix(4), &[1.0; 4]).unwrap();
        let h = vec![0.07, 0.21, 0.33, 0.48];
        let dirty = differing_columns(p_old.matrix().csc(), p_new.matrix().csc());
        assert!(!dirty.is_empty(), "A(1) and A(4) must differ somewhere");
        for owned in [vec![0usize, 1], vec![2, 3], vec![1, 3], vec![0, 1, 2, 3]] {
            let mut local_of = vec![usize::MAX; 4];
            for (t, &i) in owned.iter().enumerate() {
                local_of[i] = t;
            }
            // F = consistent fluid of the old system over the owned slice
            let full_f = p_old.fluid(&h);
            let mut f: Vec<f64> = owned.iter().map(|&i| full_f[i]).collect();
            let halo: Vec<(usize, f64)> = dirty.iter().map(|&u| (u, h[u])).collect();
            let touched = rebase_b_slice_local(
                p_old.matrix().csc(),
                p_new.matrix().csc(),
                &halo,
                &local_of,
                &mut f,
            );
            let want = rebase_b_slice(p_new.matrix(), &owned, &h, p_new.b());
            for t in 0..owned.len() {
                assert!(
                    (f[t] - want[t]).abs() < 1e-12,
                    "owned {owned:?} slot {t}: {} vs {}",
                    f[t],
                    want[t]
                );
            }
            for &t in &touched {
                assert!(t < owned.len(), "touched slot out of range");
            }
        }
    }

    /// Columns where P' = P contribute no delta, and zero-history columns
    /// are skipped entirely.
    #[test]
    fn local_delta_ignores_clean_and_zero_history_columns() {
        let p = FixedPointProblem::from_linear_system(&paper_matrix(2), &[1.0; 4]).unwrap();
        let h = vec![0.1, 0.0, 0.3, 0.0];
        let local_of: Vec<usize> = (0..4).collect();
        let mut f = p.fluid(&h);
        let before = f.clone();
        // identical matrices: every "dirty" column's delta is zero
        let halo: Vec<(usize, f64)> = (0..4).map(|u| (u, h[u])).collect();
        let touched =
            rebase_b_slice_local(p.matrix().csc(), p.matrix().csc(), &halo, &local_of, &mut f);
        for t in 0..4 {
            assert!((f[t] - before[t]).abs() < 1e-15);
        }
        // only nonzero-history columns walk their entries at all: every
        // touched slot is a row of such a column
        let live_cols: Vec<usize> = (0..4).filter(|&u| h[u] != 0.0).collect();
        for &t in &touched {
            let reachable = live_cols.iter().any(|&u| {
                let (rows, _) = p.matrix().csc().col(u);
                rows.contains(&t)
            });
            assert!(reachable, "slot {t} touched by a zero-history column");
        }
        assert!(differing_columns(p.matrix().csc(), p.matrix().csc()).is_empty());
    }
}
