//! §3.2 — live evolution of P: rebase the running computation onto a new
//! matrix P' without restarting and without global synchronization.
//!
//! If H is the history accumulated so far under (P, B), the remaining work
//! for the *new* system `X' = P'·X' + B` is the fixed point of
//!
//! ```text
//! Y = P'·Y + B'   with   B' = F + (P'−P)·H = P'·H + B − H
//! ```
//!
//! and `X' = H + Y`. Each PID can compute its own slice of B' locally from
//! its rows of P' (the middle expression is the paper's; the right-hand
//! form shows only P' is actually needed). This is Theorem 4 of [4]
//! operationalized.
//!
//! For the **V1 / H-form** scheme there is an even simpler equivalent: the
//! in-place update `H_i ← L_i(P')·H + B_i` converges to X' from *any*
//! starting point, so switching the matrix and keeping H warm is already
//! correct; [`rebase_b`] is what the **fluid form (V2)** needs, where F
//! must be reset to the consistent `F'₀ = B'`.

use crate::error::{DiterError, Result};
use crate::sparse::SparseMatrix;

/// Compute the rebased offset `B' = P'·H + B − H` (all coordinates).
pub fn rebase_b(p_new: &SparseMatrix, h: &[f64], b: &[f64]) -> Result<Vec<f64>> {
    if h.len() != p_new.n() || b.len() != p_new.n() {
        return Err(DiterError::shape("rebase_b", p_new.n(), h.len()));
    }
    let mut out = p_new.csr().matvec(h)?;
    for i in 0..out.len() {
        out[i] += b[i] - h[i];
    }
    Ok(out)
}

/// Compute only the owned slice of B' (what one PID does locally):
/// `B'_i = L_i(P')·H + B_i − H_i` for `i ∈ owned`.
pub fn rebase_b_slice(
    p_new: &SparseMatrix,
    owned: &[usize],
    h: &[f64],
    b: &[f64],
) -> Vec<f64> {
    let csr = p_new.csr();
    owned
        .iter()
        .map(|&i| csr.row_dot(i, h) + b[i] - h[i])
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::paper_matrix;
    use crate::linalg::vec_ops::dist_inf;
    use crate::solver::{DIteration, FixedPointProblem, SolveOptions, Solver};

    /// The §5.2 scenario: run on P (from A), partially converge, switch to
    /// P' (from A'), rebase, finish — the result must equal the cold-start
    /// solution of the new system.
    #[test]
    fn rebase_reaches_new_limit() {
        let p_old = FixedPointProblem::from_linear_system(&paper_matrix(1), &[1.0; 4]).unwrap();
        let p_new = FixedPointProblem::from_linear_system(&paper_matrix(4), &[1.0; 4]).unwrap();
        // partial run on the old system
        let opts = SolveOptions {
            tol: 0.0,
            max_cost: 5.0,
            trace_every: 0.0,
            exact: None,
        };
        let partial = DIteration::cyclic().solve(&p_old, &opts).unwrap();
        let h = partial.x.clone();
        // rebase: Y = P'Y + B' ; X' = H + Y
        let b_prime = rebase_b(p_new.matrix(), &h, p_new.b()).unwrap();
        let sub = FixedPointProblem::new(p_new.matrix().clone(), b_prime).unwrap();
        let y = DIteration::cyclic()
            .solve(&sub, &SolveOptions::default())
            .unwrap();
        let x: Vec<f64> = h.iter().zip(&y.x).map(|(a, b)| a + b).collect();
        let exact = p_new.exact_solution().unwrap();
        assert!(dist_inf(&x, &exact) < 1e-9, "dist {}", dist_inf(&x, &exact));
    }

    #[test]
    fn slice_matches_full() {
        let p_new = FixedPointProblem::from_linear_system(&paper_matrix(4), &[1.0; 4]).unwrap();
        let h = vec![0.1, 0.2, 0.3, 0.4];
        let full = rebase_b(p_new.matrix(), &h, p_new.b()).unwrap();
        let slice = rebase_b_slice(p_new.matrix(), &[1, 3], &h, p_new.b());
        assert_eq!(slice, vec![full[1], full[3]]);
    }

    #[test]
    fn identity_update_is_plain_fluid() {
        // P' = P ⇒ B' = F (the current fluid) — eq. 4 rearranged
        let p = FixedPointProblem::from_linear_system(&paper_matrix(2), &[1.0; 4]).unwrap();
        let h = vec![0.05, 0.1, 0.15, 0.2];
        let b_prime = rebase_b(p.matrix(), &h, p.b()).unwrap();
        let f = p.fluid(&h);
        for i in 0..4 {
            assert!((b_prime[i] - f[i]).abs() < 1e-15);
        }
    }

    #[test]
    fn shape_errors() {
        let p = FixedPointProblem::from_linear_system(&paper_matrix(1), &[1.0; 4]).unwrap();
        assert!(rebase_b(p.matrix(), &[0.0; 3], p.b()).is_err());
    }
}
