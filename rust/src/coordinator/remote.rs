//! Process-per-worker distributed solve over the TCP wire transport
//! (`diter stream --listen` / `--connect`, DESIGN.md §8.6).
//!
//! One **coordinator** process accepts `k` worker processes on a control
//! socket, assigns each a PID, and ships the *recipe* for the problem —
//! the graph-generation parameters, not the matrix — so every process
//! regenerates the identical [`FixedPointProblem`] locally (the
//! generators are seeded and deterministic). Workers then open their
//! data-plane [`WireHub`] endpoints, exchange listening addresses
//! through the coordinator (JOINED → PEERS), and run the ordinary
//! [`WorkerCore`] fluid loop: the same code path the in-process
//! engines use, pointed at a TCP endpoint instead of a bus endpoint.
//!
//! Convergence is monitored with the paper's exact invariant, assembled
//! from per-process REPORT frames: each worker reports its published
//! remaining fluid plus its *sender-side* in-flight account (mass it
//! has written to a socket and not yet seen ACKed — see
//! [`WireHub::remote`]). The coordinator declares quiescence only when
//! `Σ undelivered == 0` **and** `Σ published + Σ in-flight < tol` hold
//! across three consecutive polls, mirroring
//! [`super::monitor::run_monitor`].
//!
//! Scope (documented limitation): remote mode is a **one-shot V2-style
//! solve over a static partition of a generated problem**. The elastic
//! pool, adaptive repartitioning, and streaming epoch protocols stay
//! in-process — their control traffic rides the same wire frames, but
//! the cross-process orchestration of spawn/retire/rebase is future
//! work (ROADMAP).

use std::io::ErrorKind;
use std::net::{IpAddr, TcpListener, TcpStream};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::coordinator::monitor::MonitorState;
use crate::coordinator::worker::{WorkerCore, WorkerMsg, WORKER_METRICS};
use crate::coordinator::{DistributedConfig, TransportKind};
use crate::error::{DiterError, Result};
use crate::graph::generators::power_law_web_graph;
use crate::graph::pagerank::pagerank_system;
use crate::partition::{OwnershipTable, Partition};
use crate::solver::FixedPointProblem;
use crate::transport::wire::{
    corrupt, read_ctrl_frame, read_deltas, read_f64_slice, read_varint, write_ctrl_frame,
    write_deltas, write_f64_slice, write_varint, WireCodec,
};
use crate::transport::{BusConfig, WireHub};

/// Dangling-page fraction baked into the generated PageRank workload
/// (matches the `stream`/`pagerank` CLI paths).
const DANGLING_FRAC: f64 = 0.1;

/// How often a worker emits a REPORT frame.
const REPORT_EVERY: Duration = Duration::from_millis(25);

/// Consecutive quiescent polls required before shutdown (the same
/// stability rule as [`super::monitor::run_monitor`]).
const STABLE_POLLS: u32 = 3;

// ---------------------------------------------------------------------------
// Control-plane messages
// ---------------------------------------------------------------------------

/// The problem recipe the coordinator ships in ASSIGN: enough to
/// regenerate the identical [`FixedPointProblem`] in every process.
#[derive(Clone, Debug, PartialEq)]
pub struct RemoteParams {
    /// number of coordinates (graph nodes)
    pub n: usize,
    /// average out-degree of the generated web graph
    pub avg_out: usize,
    /// PageRank damping factor
    pub damping: f64,
    /// generator + worker RNG seed
    pub seed: u64,
    /// stop when total remaining fluid drops below this
    pub tol: f64,
    /// coordinator-enforced wall-clock cap
    pub max_wall: Duration,
    /// declare a worker dead when no REPORT arrived for this long
    /// (None = never). Workers report every [`REPORT_EVERY`] (25ms), so
    /// anything comfortably above that — e.g. 1–5s — is safe; remote
    /// workers are one-shot, so a death fails the run fast with
    /// [`DiterError::WorkerDied`] instead of spinning to `max_wall`.
    pub heartbeat: Option<Duration>,
}

/// Control-plane protocol (DESIGN.md §8.6): every variant is one frame
/// on the coordinator⇆worker control socket. Payload tags live in the
/// `0x20` block, disjoint from the data-plane tags (`0x10` block) and
/// the framing kinds (`0x01`–`0x04`).
#[derive(Clone, Debug, PartialEq)]
pub enum WireCtrl {
    /// worker → coordinator: first frame after connecting
    Join,
    /// coordinator → worker: your PID, the worker count, and the recipe
    Assign {
        pid: usize,
        k: usize,
        params: RemoteParams,
    },
    /// worker → coordinator: my data-plane listening address
    Joined { addr: String },
    /// coordinator → worker: every PID's data-plane address, by slot
    Peers { addrs: Vec<String> },
    /// coordinator → worker: begin diffusing
    Start,
    /// worker → coordinator: periodic accounting snapshot
    Report {
        pid: usize,
        /// published remaining fluid (local ‖F‖₁ + coalesced + foster)
        published: f64,
        /// sender-side in-flight mass (written, not yet ACKed)
        inflight: f64,
        /// sender-side undelivered message count
        undelivered: u64,
        /// cumulative scalar updates
        updates: u64,
    },
    /// coordinator → worker: stop stepping, send your STATE
    Shutdown,
    /// worker → coordinator: final owned slice of the history vector
    State { owned: Vec<usize>, h: Vec<f64> },
}

const CTRL_JOIN: u8 = 0x20;
const CTRL_ASSIGN: u8 = 0x21;
const CTRL_JOINED: u8 = 0x22;
const CTRL_PEERS: u8 = 0x23;
const CTRL_START: u8 = 0x24;
const CTRL_REPORT: u8 = 0x25;
const CTRL_SHUTDOWN: u8 = 0x26;
const CTRL_STATE: u8 = 0x27;

fn write_str(out: &mut Vec<u8>, s: &str) {
    write_varint(out, s.len() as u64);
    out.extend_from_slice(s.as_bytes());
}

fn read_str(buf: &[u8], pos: &mut usize) -> Result<String> {
    let len = read_varint(buf, pos)? as usize;
    if buf.len() - *pos < len {
        return Err(corrupt("string runs past frame"));
    }
    let s = std::str::from_utf8(&buf[*pos..*pos + len])
        .map_err(|_| corrupt("string not UTF-8"))?
        .to_string();
    *pos += len;
    Ok(s)
}

impl WireCodec for WireCtrl {
    fn encode(&self, out: &mut Vec<u8>) {
        match self {
            WireCtrl::Join => out.push(CTRL_JOIN),
            WireCtrl::Assign { pid, k, params } => {
                out.push(CTRL_ASSIGN);
                write_varint(out, *pid as u64);
                write_varint(out, *k as u64);
                write_varint(out, params.n as u64);
                write_varint(out, params.avg_out as u64);
                write_f64_slice(out, &[params.damping, params.tol]);
                write_varint(out, params.seed);
                write_varint(out, params.max_wall.as_millis() as u64);
                // 0 = no heartbeat (the Option round-trips through the
                // sentinel: a 0ms deadline would be meaningless anyway)
                write_varint(
                    out,
                    params.heartbeat.map(|h| h.as_millis() as u64).unwrap_or(0),
                );
            }
            WireCtrl::Joined { addr } => {
                out.push(CTRL_JOINED);
                write_str(out, addr);
            }
            WireCtrl::Peers { addrs } => {
                out.push(CTRL_PEERS);
                write_varint(out, addrs.len() as u64);
                for a in addrs {
                    write_str(out, a);
                }
            }
            WireCtrl::Start => out.push(CTRL_START),
            WireCtrl::Report {
                pid,
                published,
                inflight,
                undelivered,
                updates,
            } => {
                out.push(CTRL_REPORT);
                write_varint(out, *pid as u64);
                write_f64_slice(out, &[*published, *inflight]);
                write_varint(out, *undelivered);
                write_varint(out, *updates);
            }
            WireCtrl::Shutdown => out.push(CTRL_SHUTDOWN),
            WireCtrl::State { owned, h } => {
                debug_assert_eq!(owned.len(), h.len());
                out.push(CTRL_STATE);
                write_varint(out, owned.len() as u64);
                write_deltas(out, owned.iter().map(|&c| c as u64));
                write_f64_slice(out, h);
            }
        }
    }

    fn decode(buf: &[u8]) -> Result<WireCtrl> {
        let Some(&tag) = buf.first() else {
            return Err(corrupt("empty control payload"));
        };
        let mut pos = 1;
        let msg = match tag {
            CTRL_JOIN => WireCtrl::Join,
            CTRL_ASSIGN => {
                let pid = read_varint(buf, &mut pos)? as usize;
                let k = read_varint(buf, &mut pos)? as usize;
                let n = read_varint(buf, &mut pos)? as usize;
                let avg_out = read_varint(buf, &mut pos)? as usize;
                let dt = read_f64_slice(buf, &mut pos, 2)?;
                let seed = read_varint(buf, &mut pos)?;
                let max_wall = Duration::from_millis(read_varint(buf, &mut pos)?);
                let hb = read_varint(buf, &mut pos)?;
                let heartbeat = (hb > 0).then(|| Duration::from_millis(hb));
                WireCtrl::Assign {
                    pid,
                    k,
                    params: RemoteParams {
                        n,
                        avg_out,
                        damping: dt[0],
                        seed,
                        tol: dt[1],
                        max_wall,
                        heartbeat,
                    },
                }
            }
            CTRL_JOINED => WireCtrl::Joined {
                addr: read_str(buf, &mut pos)?,
            },
            CTRL_PEERS => {
                let count = read_varint(buf, &mut pos)? as usize;
                if count > buf.len() {
                    return Err(corrupt("peer count exceeds frame"));
                }
                let mut addrs = Vec::with_capacity(count);
                for _ in 0..count {
                    addrs.push(read_str(buf, &mut pos)?);
                }
                WireCtrl::Peers { addrs }
            }
            CTRL_START => WireCtrl::Start,
            CTRL_REPORT => {
                let pid = read_varint(buf, &mut pos)? as usize;
                let pi = read_f64_slice(buf, &mut pos, 2)?;
                let undelivered = read_varint(buf, &mut pos)?;
                let updates = read_varint(buf, &mut pos)?;
                WireCtrl::Report {
                    pid,
                    published: pi[0],
                    inflight: pi[1],
                    undelivered,
                    updates,
                }
            }
            CTRL_SHUTDOWN => WireCtrl::Shutdown,
            CTRL_STATE => {
                let count = read_varint(buf, &mut pos)? as usize;
                let owned = read_deltas(buf, &mut pos, count)?
                    .into_iter()
                    .map(|v| v as usize)
                    .collect();
                let h = read_f64_slice(buf, &mut pos, count)?;
                WireCtrl::State { owned, h }
            }
            other => return Err(corrupt(&format!("unknown control tag {other:#04x}"))),
        };
        if pos != buf.len() {
            return Err(corrupt("trailing bytes after control payload"));
        }
        Ok(msg)
    }
}

// ---------------------------------------------------------------------------
// Control connection: blocking frames + a non-blocking poll
// ---------------------------------------------------------------------------

/// One control-plane socket. Frames are written and read blocking (they
/// are small and the peer is cooperative); [`CtrlConn::try_recv`] gives
/// the run-phase a non-blocking poll by peeking before committing to a
/// blocking frame read — once the length prefix's first byte is
/// visible, the rest of the (already fully written and flushed) frame
/// is imminent.
struct CtrlConn {
    stream: TcpStream,
}

impl CtrlConn {
    fn send(&mut self, msg: &WireCtrl) -> Result<()> {
        write_ctrl_frame(&mut self.stream, msg)
    }

    fn recv(&mut self) -> Result<WireCtrl> {
        read_ctrl_frame(&mut self.stream)
    }

    /// Non-blocking poll: `Ok(None)` when no frame has started arriving.
    /// A closed peer is an error — the protocol ends with an explicit
    /// frame exchange, never a silent hangup.
    fn try_recv(&mut self) -> Result<Option<WireCtrl>> {
        self.stream.set_nonblocking(true)?;
        let mut probe = [0u8; 1];
        let ready = match self.stream.peek(&mut probe) {
            Ok(0) => {
                let _ = self.stream.set_nonblocking(false);
                return Err(DiterError::Coordinator(
                    "control peer hung up mid-protocol".into(),
                ));
            }
            Ok(_) => true,
            Err(e) if e.kind() == ErrorKind::WouldBlock => false,
            Err(e) => {
                let _ = self.stream.set_nonblocking(false);
                return Err(e.into());
            }
        };
        self.stream.set_nonblocking(false)?;
        if ready {
            Ok(Some(self.recv()?))
        } else {
            Ok(None)
        }
    }
}

// ---------------------------------------------------------------------------
// Coordinator
// ---------------------------------------------------------------------------

/// What a remote solve produced, as assembled at the coordinator.
#[derive(Clone, Debug)]
pub struct RemoteSummary {
    /// the assembled solution (every coordinate from its owner's STATE)
    pub x: Vec<f64>,
    /// authoritative residual of `x`, recomputed against the
    /// regenerated problem
    pub residual: f64,
    pub converged: bool,
    /// total scalar updates across all worker processes
    pub total_updates: u64,
    pub wall_secs: f64,
}

fn regenerate(params: &RemoteParams) -> Result<Arc<FixedPointProblem>> {
    let g = power_law_web_graph(params.n, params.avg_out, DANGLING_FRAC, params.seed);
    let sys = pagerank_system(&g, params.damping, true)?;
    Ok(Arc::new(FixedPointProblem::new(sys.matrix, sys.b)?))
}

/// Run the coordinator role: bind `listen`, accept `k` workers, drive
/// the join → assign → peers → start → report → shutdown → state
/// protocol, and assemble the solution.
pub fn run_coordinator(listen: &str, k: usize, params: &RemoteParams) -> Result<RemoteSummary> {
    let listener = TcpListener::bind(listen)?;
    serve_coordinator(listener, k, params)
}

/// [`run_coordinator`] over an already-bound listener (lets tests and
/// embedders use an OS-assigned port).
pub fn serve_coordinator(
    listener: TcpListener,
    k: usize,
    params: &RemoteParams,
) -> Result<RemoteSummary> {
    if k == 0 {
        return Err(DiterError::Coordinator("need at least one worker".into()));
    }
    // Join phase: accept k workers; join order is PID order.
    let mut conns: Vec<CtrlConn> = Vec::with_capacity(k);
    for pid in 0..k {
        let (stream, peer) = listener.accept()?;
        stream.set_nodelay(true)?;
        let mut conn = CtrlConn { stream };
        match conn.recv()? {
            WireCtrl::Join => {}
            other => {
                return Err(DiterError::Coordinator(format!(
                    "expected JOIN from {peer}, got {other:?}"
                )))
            }
        }
        conn.send(&WireCtrl::Assign {
            pid,
            k,
            params: params.clone(),
        })?;
        eprintln!("[coordinator] worker {pid}/{k} joined from {peer}");
        conns.push(conn);
    }

    // Address exchange: collect every JOINED, then broadcast PEERS + START.
    let mut addrs = vec![String::new(); k];
    for (pid, conn) in conns.iter_mut().enumerate() {
        match conn.recv()? {
            WireCtrl::Joined { addr } => addrs[pid] = addr,
            other => {
                return Err(DiterError::Coordinator(format!(
                    "expected JOINED from pid {pid}, got {other:?}"
                )))
            }
        }
    }
    for conn in conns.iter_mut() {
        conn.send(&WireCtrl::Peers {
            addrs: addrs.clone(),
        })?;
        conn.send(&WireCtrl::Start)?;
    }
    eprintln!("[coordinator] {k} workers started, monitoring convergence");

    // Run phase: poll REPORTs, apply the exact-monitor quiescence rule.
    let start = Instant::now();
    let mut latest: Vec<Option<(f64, f64, u64, u64)>> = vec![None; k];
    let mut last_seen: Vec<Instant> = vec![Instant::now(); k];
    let mut stable = 0u32;
    let mut converged = false;
    loop {
        for (cpid, conn) in conns.iter_mut().enumerate() {
            loop {
                match conn.try_recv() {
                    Ok(None) => break,
                    Ok(Some(msg)) => match msg {
                        WireCtrl::Report {
                            pid,
                            published,
                            inflight,
                            undelivered,
                            updates,
                        } if pid < k => {
                            latest[pid] = Some((published, inflight, undelivered, updates));
                            last_seen[pid] = Instant::now();
                        }
                        other => {
                            return Err(DiterError::Coordinator(format!(
                                "expected REPORT, got {other:?}"
                            )))
                        }
                    },
                    Err(_) => {
                        // EOF / reset mid-run: remote workers are
                        // one-shot, so fail fast with the culprit —
                        // its last REPORT is void (quiescence can never
                        // be proven from a dead worker's numbers) and
                        // spinning to max_wall helps nobody
                        latest[cpid] = None;
                        return Err(DiterError::WorkerDied(cpid));
                    }
                }
            }
        }
        if let Some(hb) = params.heartbeat {
            for pid in 0..k {
                if last_seen[pid].elapsed() > hb {
                    // silent death (no FIN reached us — e.g. a wedged
                    // process or a dropped link): same verdict as EOF
                    latest[pid] = None;
                    return Err(DiterError::WorkerDied(pid));
                }
            }
        }
        if latest.iter().all(Option::is_some) {
            let undelivered: u64 = latest.iter().map(|r| r.unwrap().2).sum();
            // per-process gating, as in BusMonitor::inflight_or_zero:
            // with nothing undelivered the in-flight float is residue,
            // not mass
            let total: f64 = latest
                .iter()
                .map(|r| {
                    let (published, inflight, und, _) = r.unwrap();
                    published + if und > 0 { inflight } else { 0.0 }
                })
                .sum();
            if undelivered == 0 && total < params.tol {
                stable += 1;
                if stable >= STABLE_POLLS {
                    converged = true;
                    break;
                }
            } else {
                stable = 0;
            }
        }
        if start.elapsed() > params.max_wall {
            break;
        }
        std::thread::sleep(Duration::from_millis(5));
    }

    // Shutdown: every worker answers with its STATE (late REPORTs may
    // still be queued ahead of it).
    for conn in conns.iter_mut() {
        conn.send(&WireCtrl::Shutdown)?;
    }
    let mut x = vec![0.0; params.n];
    let mut total_updates = 0u64;
    for (pid, conn) in conns.iter_mut().enumerate() {
        // the gather blocks on each worker in turn: bound it so a worker
        // that died between the last poll and its SHUTDOWN cannot hang
        // the coordinator forever
        let _ = conn.stream.set_read_timeout(Some(Duration::from_secs(30)));
        loop {
            match conn.recv().map_err(|_| DiterError::WorkerDied(pid))? {
                WireCtrl::Report { pid, updates, .. } if pid < k => {
                    if let Some(r) = latest.get_mut(pid).and_then(|r| r.as_mut()) {
                        r.3 = updates;
                    }
                }
                WireCtrl::State { owned, h } => {
                    if owned.len() != h.len() {
                        return Err(DiterError::Coordinator(format!(
                            "pid {pid} STATE shape mismatch"
                        )));
                    }
                    for (&c, &hv) in owned.iter().zip(&h) {
                        if c >= params.n {
                            return Err(DiterError::Coordinator(format!(
                                "pid {pid} STATE coordinate {c} out of range"
                            )));
                        }
                        x[c] = hv;
                    }
                    break;
                }
                other => {
                    return Err(DiterError::Coordinator(format!(
                        "expected STATE from pid {pid}, got {other:?}"
                    )))
                }
            }
        }
    }
    total_updates += latest.iter().flatten().map(|r| r.3).sum::<u64>();

    let problem = regenerate(params)?;
    let residual = problem.residual_norm(&x);
    Ok(RemoteSummary {
        x,
        residual,
        converged,
        total_updates,
        wall_secs: start.elapsed().as_secs_f64(),
    })
}

// ---------------------------------------------------------------------------
// Worker
// ---------------------------------------------------------------------------

/// Run the worker role: connect to the coordinator at `connect`, join,
/// regenerate the assigned problem, and diffuse until SHUTDOWN.
/// `bind_ip` is the local interface the data-plane listener binds
/// (must be reachable by peer workers).
pub fn run_worker(connect: &str, bind_ip: IpAddr) -> Result<()> {
    let stream = TcpStream::connect(connect)?;
    stream.set_nodelay(true)?;
    let mut ctrl = CtrlConn { stream };
    ctrl.send(&WireCtrl::Join)?;
    let (pid, k, params) = match ctrl.recv()? {
        WireCtrl::Assign { pid, k, params } => (pid, k, params),
        other => {
            return Err(DiterError::Coordinator(format!(
                "expected ASSIGN, got {other:?}"
            )))
        }
    };
    eprintln!(
        "[worker {pid}] assigned: n={} k={k} seed={} tol={:.0e}",
        params.n, params.seed, params.tol
    );

    let problem = regenerate(&params)?;
    let partition = Partition::contiguous(params.n, k)?;
    let cfg = DistributedConfig::new(partition.clone())
        .with_tol(params.tol)
        .with_seed(params.seed)
        .with_transport(TransportKind::Wire);

    let hub = WireHub::<WorkerMsg>::remote(
        k,
        bind_ip,
        &BusConfig {
            latency: None,
            seed: params.seed,
            flush: cfg.wire_flush,
            // remote workers are one-shot: a death fails the run fast
            // (WorkerDied) rather than recovering in place, so the
            // eager local-commit accounting stays in force
            ack_release: false,
        },
        WORKER_METRICS,
    );
    let ep = hub.add_endpoint(pid)?;
    ctrl.send(&WireCtrl::Joined {
        addr: ep.local_addr().to_string(),
    })?;

    match ctrl.recv()? {
        WireCtrl::Peers { addrs } => {
            if addrs.len() != k {
                return Err(DiterError::Coordinator(format!(
                    "PEERS table has {} slots, expected {k}",
                    addrs.len()
                )));
            }
            for (i, a) in addrs.iter().enumerate() {
                if i == pid {
                    continue;
                }
                let addr = a.parse().map_err(|_| {
                    DiterError::Coordinator(format!("bad peer address {a:?} for pid {i}"))
                })?;
                hub.set_peer_addr(i, addr);
            }
        }
        other => {
            return Err(DiterError::Coordinator(format!(
                "expected PEERS, got {other:?}"
            )))
        }
    }
    match ctrl.recv()? {
        WireCtrl::Start => {}
        other => {
            return Err(DiterError::Coordinator(format!(
                "expected START, got {other:?}"
            )))
        }
    }

    let table = OwnershipTable::new(partition);
    let state = MonitorState::with_capacity(k, k);
    let mut core = WorkerCore::new(pid, Box::new(ep), problem, table, state.clone(), cfg);

    // The fluid loop, with a worker-side wall cap twice the
    // coordinator's in case the coordinator dies without a SHUTDOWN.
    let start = Instant::now();
    let wall_cap = params.max_wall * 2 + Duration::from_secs(5);
    let mut last_report = Instant::now();
    loop {
        match ctrl.try_recv()? {
            Some(WireCtrl::Shutdown) => break,
            Some(other) => {
                return Err(DiterError::Coordinator(format!(
                    "expected SHUTDOWN, got {other:?}"
                )))
            }
            None => {}
        }
        let (got_fluid, r_k) = core.step();
        if !got_fluid && r_k == 0.0 {
            // locally drained: don't spin the socket at full speed
            std::thread::sleep(Duration::from_micros(200));
        }
        if last_report.elapsed() >= REPORT_EVERY {
            last_report = Instant::now();
            let mon = hub.monitor();
            ctrl.send(&WireCtrl::Report {
                pid,
                published: state.published_values()[pid],
                inflight: mon.inflight(),
                undelivered: mon.undelivered(),
                updates: state.update_counts()[pid],
            })?;
        }
        if start.elapsed() > wall_cap {
            return Err(DiterError::Coordinator(
                "worker wall-clock cap exceeded with no SHUTDOWN".into(),
            ));
        }
    }

    let (owned, h) = core.finish();
    eprintln!("[worker {pid}] shutting down: {} coordinates held", owned.len());
    ctrl.send(&WireCtrl::State { owned, h })?;
    Ok(())
}

// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::Ipv4Addr;

    fn round_trip(msg: &WireCtrl) -> WireCtrl {
        let mut buf = Vec::new();
        msg.encode(&mut buf);
        WireCtrl::decode(&buf).expect("decode what we encoded")
    }

    #[test]
    fn ctrl_messages_round_trip() {
        let params = RemoteParams {
            n: 5000,
            avg_out: 8,
            damping: 0.85,
            seed: 7,
            tol: 1e-9,
            max_wall: Duration::from_secs(60),
            heartbeat: Some(Duration::from_secs(2)),
        };
        let msgs = [
            WireCtrl::Join,
            WireCtrl::Assign {
                pid: 3,
                k: 4,
                params,
            },
            WireCtrl::Joined {
                addr: "127.0.0.1:45123".into(),
            },
            WireCtrl::Peers {
                addrs: vec!["127.0.0.1:1".into(), "10.0.0.2:2".into()],
            },
            WireCtrl::Start,
            WireCtrl::Report {
                pid: 1,
                published: 0.5,
                inflight: 1e-3,
                undelivered: 2,
                updates: 12345,
            },
            WireCtrl::Shutdown,
            WireCtrl::State {
                owned: vec![4, 5, 6, 100],
                h: vec![0.1, 0.2, 0.3, 0.4],
            },
        ];
        for msg in &msgs {
            assert_eq!(&round_trip(msg), msg);
        }
    }

    #[test]
    fn ctrl_decode_rejects_garbage() {
        assert!(WireCtrl::decode(&[]).is_err());
        assert!(WireCtrl::decode(&[0x7F]).is_err());
        // trailing bytes after a tag-only message
        assert!(WireCtrl::decode(&[CTRL_START, 0]).is_err());
        // truncated ASSIGN
        let mut buf = Vec::new();
        WireCtrl::Assign {
            pid: 0,
            k: 2,
            params: RemoteParams {
                n: 100,
                avg_out: 4,
                damping: 0.85,
                seed: 1,
                tol: 1e-9,
                max_wall: Duration::from_secs(1),
                heartbeat: None,
            },
        }
        .encode(&mut buf);
        for cut in 0..buf.len() {
            assert!(WireCtrl::decode(&buf[..cut]).is_err(), "cut at {cut}");
        }
    }

    /// End-to-end remote solve with the coordinator and two "processes"
    /// as threads: three separate hubs, three accounting domains, real
    /// TCP on both planes — exactly the process topology minus fork().
    #[test]
    fn remote_solve_two_workers() {
        let listener = TcpListener::bind("127.0.0.1:0").expect("bind");
        let addr = listener.local_addr().expect("addr").to_string();
        let params = RemoteParams {
            n: 400,
            avg_out: 6,
            damping: 0.85,
            seed: 11,
            tol: 1e-10,
            max_wall: Duration::from_secs(30),
            heartbeat: Some(Duration::from_secs(5)),
        };
        let workers: Vec<_> = (0..2)
            .map(|_| {
                let addr = addr.clone();
                std::thread::spawn(move || {
                    run_worker(&addr, IpAddr::V4(Ipv4Addr::LOCALHOST))
                })
            })
            .collect();
        let summary = serve_coordinator(listener, 2, &params).expect("coordinator");
        for w in workers {
            w.join().expect("worker thread").expect("worker ok");
        }
        assert!(summary.converged, "should quiesce well inside the cap");
        assert!(
            summary.residual < 1e-8,
            "assembled residual {} too large",
            summary.residual
        );
        assert!(summary.total_updates > 0);
        // PageRank mass: Σx ≈ 1 for the damped system with teleport b
        let mass: f64 = summary.x.iter().sum();
        assert!((mass - 1.0).abs() < 1e-6, "Σx = {mass}");
    }
}
