//! The paper's contribution: asynchronous distributed D-iteration.
//!
//! Two schemes over a [`Partition`] of the coordinates (one worker thread
//! per `Ω_k`, communicating over the [`crate::transport`] bus):
//!
//! * [`v1`] — full-H scheme (§3.1): every PID holds the complete history
//!   vector, sweeps its own rows (eq. 6), and broadcasts its slice when its
//!   local remaining fluid crosses the threshold `T_k` (§4) or when a peer
//!   update arrives (§4.3).
//! * [`v2`] — partial-state fluid scheme (§3.3): every PID holds only its
//!   local `(B, H, F)` slice and ships fluid parcels `f·p_{ji}` to the
//!   owner of j, coalescing small parcels (§3.3) and never losing fluid
//!   (transport ack/retention). Convergence is monitored *exactly* by
//!   total fluid = local ‖F‖₁ + coalesced + in-flight.
//!
//! [`sim`] contains a deterministic lockstep simulator of both schemes
//! used to regenerate the paper's figures (same protocol, reproducible
//! interleaving), [`update`] implements the §3.2 live matrix-evolution
//! rebase `B' = F + (P'−P)·H`, and [`stream`] builds on it: a long-running
//! [`stream::StreamingEngine`] that keeps the V2 workers diffusing across
//! graph-mutation epochs instead of restarting.
//!
//! [`worker`] is the shared per-PID fluid loop both [`v2`] and [`stream`]
//! instantiate: it routes through a **versioned ownership table** rather
//! than a static partition, which is what makes §4.3's speed adaptation a
//! *live* operation — [`adaptive`] supplies the policy, the worker core
//! ships `(H, B, F)` slices between PIDs over the bus (`Handoff` control
//! messages) without stopping the diffusion or losing a unit of fluid.
//!
//! [`pool`] owns the worker lifecycles behind both engines: a
//! [`pool::WorkerPool`] scheduler that, with [`ElasticConfig`] set, also
//! **spawns** new live workers (runtime bus endpoints, adopt-from-empty
//! via the handoff machinery) for persistent stragglers and **retires**
//! idle ones mid-convergence — the elastic half of §4.3 (DESIGN.md §6).

pub mod adaptive;
pub mod codec;
pub mod monitor;
pub mod pool;
pub mod query;
pub mod remote;
pub mod sim;
pub mod stream;
pub mod update;
pub mod v1;
pub mod v2;
pub mod worker;

pub use adaptive::{AdaptiveConfig, AdaptiveController, AdaptivePolicy, HandoffPlan};
pub use pool::{ElasticConfig, PoolStats, WorkerPool};
pub use query::{Query, QueryRecord, QuerySet, QueryState, ServeConfig, ServeEngine, ServedQuery};
pub use stream::{EpochReport, StreamSummary, StreamingEngine};
pub use worker::{Handoff, WorkerMsg};

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Duration;

use crate::metrics::ConvergenceTrace;
use crate::partition::Partition;
use crate::solver::SequenceKind;
use crate::transport::{CoalescePolicy, FlushPolicy};
pub use crate::transport::TransportKind;

/// Which inner diffusion kernel the worker core runs. The default is the
/// partition-local fast path; the pre-refactor global-walk kernel stays
/// selectable so the recorded perf trajectory
/// (`benches/streaming_churn.rs` → `BENCH_stream.json`) can measure the
/// before/after on any machine.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum KernelKind {
    /// Reindexed local CSC block + SoA remnant accumulators — no
    /// `local_of` lookups, no global column walks in the inner loop.
    #[default]
    LocalBlock,
    /// Global-CSC column walk with per-coordinate routing (the pre-PR
    /// baseline shape, kept for measured comparisons).
    GlobalWalk,
    /// Batched variant of [`Self::LocalBlock`] (DESIGN.md §9): drains a
    /// small batch of greedy-queue slots per iteration, walks their local
    /// CSC columns with 4-wide unrolled f64 accumulation, and defers
    /// greedy-queue refiling to one pass over a touched-slot journal. All
    /// scratch is preallocated — the steady-state quantum performs zero
    /// heap allocations (asserted by the counting-allocator test).
    Blocked,
}

impl KernelKind {
    /// Parse a CLI/env name: `local`, `global`, `blocked`.
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "local" => Some(Self::LocalBlock),
            "global" => Some(Self::GlobalWalk),
            "blocked" => Some(Self::Blocked),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::LocalBlock => "local",
            Self::GlobalWalk => "global",
            Self::Blocked => "blocked",
        }
    }

    /// Whether this kernel diffuses against a built
    /// [`crate::sparse::LocalSystem`] — and therefore shares every
    /// LocalSystem build / patch / shed / adopt / retarget path with the
    /// other local kernels. The global walk is the only one that does not.
    pub fn uses_local_system(&self) -> bool {
        !matches!(self, Self::GlobalWalk)
    }
}

/// Which epoch-transition protocol the streaming engine runs when the
/// graph mutates (DESIGN.md §7). Both reach the same fixed point; they
/// differ in who computes the rebased fluid `B' = P'·H + B − H` and in
/// what crosses the wire at an epoch boundary.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum RebaseMode {
    /// V2-style leader rebase (the PR 1 protocol): quiesce handoffs,
    /// checkpoint every worker (pause + gather full H at the leader),
    /// compute each PID's `B'` slice centrally, scatter and resume.
    #[default]
    Gather,
    /// V1-style local rebase (§3.1 full/halo history): the coordinator
    /// broadcasts only the mutation delta (dirty columns); each worker
    /// recomputes its own fluid slice in place via
    /// `F' = F + (P'−P)·H`, exchanging just the halo H values of the
    /// dirty columns with owning peers ([`worker::WorkerMsg::HaloSlice`]).
    /// No leader gather, no full-H scatter, and workers never stop
    /// diffusing non-dirty fluid.
    Local,
}

impl RebaseMode {
    /// Parse a CLI/env name: `gather`, `local`.
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "gather" => Some(Self::Gather),
            "local" => Some(Self::Local),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::Gather => "gather",
            Self::Local => "local",
        }
    }
}

/// Configuration shared by both distributed schemes.
#[derive(Clone, Debug)]
pub struct DistributedConfig {
    /// how the coordinates are split into Ω_k (k() = number of PIDs)
    pub partition: Partition,
    /// diffusion order within each Ω_k (§4.2)
    pub sequence: SequenceKind,
    /// initial sharing threshold T_k (§4.1)
    pub threshold0: f64,
    /// threshold divisor α > 1 (§4.1: T_k ← T_k/α)
    pub threshold_alpha: f64,
    /// local sweeps per work quantum (the paper's Fig 1 protocol uses 2)
    pub sweeps_per_round: usize,
    /// stop when the total remaining fluid drops below this
    pub tol: f64,
    /// wall-clock safety cap
    pub max_wall: Duration,
    /// simulated message latency (None = instant)
    pub latency: Option<(Duration, Duration)>,
    /// V2 fluid regrouping policy (§3.3)
    pub coalesce: CoalescePolicy,
    /// RNG seed (sequences, latency jitter)
    pub seed: u64,
    /// live §4.3 repartitioning (None = static partition for the run)
    pub adaptive: Option<AdaptiveConfig>,
    /// elastic worker pool: spawn/retire PIDs at runtime (None = the
    /// worker count stays at partition.k() for the whole run). Subsumes
    /// `adaptive` when set — the pool scheduler handles straggler sheds
    /// itself once it is out of spawn headroom.
    pub elastic: Option<ElasticConfig>,
    /// artificially cap one PID's update rate (straggler injection for
    /// adaptive-repartitioning experiments and tests)
    pub straggler: Option<Straggler>,
    /// which inner diffusion kernel the workers run (perf comparisons)
    pub kernel: KernelKind,
    /// which epoch-transition protocol the streaming engine runs
    /// (`--rebase gather|local`; one-shot solves never rebase)
    pub rebase: RebaseMode,
    /// which message fabric carries the workers (in-process bus or
    /// loopback TCP wire). Defaults from the `DITER_TRANSPORT`
    /// environment variable so the whole test-suite can be re-run over
    /// the wire without touching a line of it.
    pub transport: TransportKind,
    /// when the wire transport flushes queued frames to the sockets
    /// (`--wire-flush-bytes/-frames/-us`; ignored by the in-process bus)
    pub wire_flush: FlushPolicy,
    /// opt-in Linux core pinning for pool-spawned workers (`--pin-cores`
    /// CLI flag; defaults from `DITER_PIN=1`): each worker thread pins
    /// itself to core `pid % available_parallelism` via a raw
    /// `sched_setaffinity` syscall ([`crate::perf::pin_to_core`]), so
    /// elastic spawns land on distinct cores. Best-effort: a no-op off
    /// Linux or under a restricting cgroup mask.
    pub pin_cores: bool,
    /// fluid lanes per coordinate (DESIGN.md §10): lane 0 is the base
    /// problem; lanes 1.. serve concurrent queries from `queries`.
    /// `lanes > 1` requires the greedy sequence (the cyclic order has no
    /// largest-fluid-anywhere rule to generalize).
    pub lanes: usize,
    /// the shared multi-tenant query registry ([`query::QuerySet`]);
    /// None = single-lane operation, identical to the pre-serving engine
    pub queries: Option<Arc<query::QuerySet>>,
    /// interval between incremental per-worker H checkpoints
    /// (`--checkpoint-every-ms`). None (the default) disables
    /// checkpointing entirely — crash recovery then reconstructs fluid
    /// from H = 0 over the lost slice (still exact, all progress on the
    /// slice rewound) and the no-failure hot path is byte-identical to
    /// the pre-crash-tolerance engine.
    pub checkpoint_every: Option<Duration>,
    /// heartbeat staleness deadline (`--heartbeat-ms`): in-process, the
    /// monitor stamps each worker's loop activity and reports stale
    /// workers through the `worker_stale_beats` gauge; over the remote
    /// control plane a worker whose REPORTs stop for this long fails the
    /// run fast with [`crate::error::DiterError::WorkerDied`]. None (the
    /// default) disables both.
    pub heartbeat: Option<Duration>,
}

/// Straggler injection: PID `pid` is throttled to at most
/// `updates_per_sec` scalar diffusions per second (a simulated slow or
/// oversubscribed machine).
#[derive(Clone, Copy, Debug)]
pub struct Straggler {
    pub pid: usize,
    pub updates_per_sec: f64,
}

impl DistributedConfig {
    pub fn new(partition: Partition) -> Self {
        Self {
            partition,
            sequence: SequenceKind::Cyclic,
            threshold0: 1e-3,
            threshold_alpha: 2.0,
            sweeps_per_round: 2,
            tol: 1e-12,
            max_wall: Duration::from_secs(60),
            latency: None,
            coalesce: CoalescePolicy::default(),
            seed: 0,
            adaptive: None,
            elastic: None,
            straggler: None,
            kernel: KernelKind::default(),
            rebase: RebaseMode::default(),
            transport: TransportKind::from_env(),
            wire_flush: FlushPolicy::default(),
            pin_cores: std::env::var("DITER_PIN")
                .map(|v| v == "1" || v.eq_ignore_ascii_case("true"))
                .unwrap_or(false),
            lanes: 1,
            queries: None,
            checkpoint_every: None,
            heartbeat: None,
        }
    }

    pub fn with_checkpoint_every(mut self, every: Duration) -> Self {
        self.checkpoint_every = Some(every);
        self
    }

    pub fn with_heartbeat(mut self, deadline: Duration) -> Self {
        self.heartbeat = Some(deadline);
        self
    }

    /// Whether any crash-tolerance feature is enabled. The transports key
    /// their exact-release accounting mode off this, so a run with both
    /// knobs off stays byte-identical to the pre-crash-tolerance engine.
    pub fn crash_tolerant(&self) -> bool {
        self.checkpoint_every.is_some() || self.heartbeat.is_some()
    }

    pub fn with_lanes(mut self, lanes: usize) -> Self {
        assert!(lanes >= 1);
        self.lanes = lanes;
        self
    }

    pub fn with_queries(mut self, queries: Arc<query::QuerySet>) -> Self {
        self.lanes = queries.lanes();
        self.queries = Some(queries);
        self
    }

    pub fn with_pin_cores(mut self, pin: bool) -> Self {
        self.pin_cores = pin;
        self
    }

    pub fn with_kernel(mut self, kernel: KernelKind) -> Self {
        self.kernel = kernel;
        self
    }

    pub fn with_transport(mut self, transport: TransportKind) -> Self {
        self.transport = transport;
        self
    }

    pub fn with_wire_flush(mut self, flush: FlushPolicy) -> Self {
        self.wire_flush = flush;
        self
    }

    pub fn with_rebase(mut self, rebase: RebaseMode) -> Self {
        self.rebase = rebase;
        self
    }

    pub fn with_sequence(mut self, s: SequenceKind) -> Self {
        self.sequence = s;
        self
    }

    pub fn with_tol(mut self, tol: f64) -> Self {
        self.tol = tol;
        self
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn with_adaptive(mut self, adaptive: AdaptiveConfig) -> Self {
        self.adaptive = Some(adaptive);
        self
    }

    pub fn with_elastic(mut self, elastic: ElasticConfig) -> Self {
        self.elastic = Some(elastic);
        self
    }

    pub fn with_straggler(mut self, pid: usize, updates_per_sec: f64) -> Self {
        self.straggler = Some(Straggler {
            pid,
            updates_per_sec,
        });
        self
    }
}

/// Result of a distributed solve.
#[derive(Clone, Debug)]
pub struct DistributedSolution {
    /// assembled solution (each coordinate from its owner's final state)
    pub x: Vec<f64>,
    /// authoritative residual of the assembled x (recomputed at the end)
    pub residual: f64,
    pub converged: bool,
    /// *parallel* cost in equivalent full passes: max over PIDs of
    /// (local scalar updates / N)
    pub cost: f64,
    /// total scalar updates across all PIDs (the work, not the makespan)
    pub total_updates: u64,
    /// wall-clock seconds
    pub wall_secs: f64,
    /// residual-bound samples collected by the monitor
    pub trace: ConvergenceTrace,
    /// transport + scheme counters snapshot
    pub metrics: BTreeMap<&'static str, u64>,
}

impl DistributedSolution {
    /// updates/second across the whole run (the hot-path throughput metric)
    pub fn updates_per_sec(&self) -> f64 {
        if self.wall_secs == 0.0 {
            0.0
        } else {
            self.total_updates as f64 / self.wall_secs
        }
    }
}
