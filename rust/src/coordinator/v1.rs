//! V1 distributed scheme (§3.1): full history vector per PID.
//!
//! Every `PID_k` holds a complete copy of H (initialized to B per §2.1.1),
//! repeatedly applies the local updates `H_i ← L_i(P)·H + B_i` for
//! `i ∈ Ω_k` (eq. 6), and shares its updated slice `(H)_{i∈Ω_k}` with all
//! other PIDs when (§4.3):
//!
//! * its local remaining fluid `r_k = Σ_{i∈Ω_k} |L_i(P)·H + B_i − H_i|`
//!   drops below the threshold `T_k` — after which `T_k ← T_k/α`; or
//! * it received a peer update since its last share (and its own slice
//!   actually changed — the "dirty" guard that keeps the literal
//!   share-on-receive rule from echoing forever once converged).
//!
//! Workers run as OS threads over the async bus; the leader runs the
//! convergence monitor and assembles the final solution from each owner's
//! slice.

use std::sync::Arc;
use std::time::Duration;

use super::monitor::{run_monitor, MonitorState};
use super::{DistributedConfig, DistributedSolution};
use crate::error::{DiterError, Result};
use crate::metrics::ConvergenceTrace;
use crate::solver::{FixedPointProblem, SequenceState};
use crate::transport::{bus, monitor_of, BusConfig, Endpoint};

/// V1 message: one PID's updated slice (values aligned with its Ω_k).
#[derive(Clone, Debug)]
pub struct SliceMsg {
    pub owner: usize,
    pub values: Vec<f64>,
}

/// The §4.1/§4.3 sharing decision for one V1 round, in one place: share
/// when the local threshold was crossed **or** a peer update arrived —
/// but only if the local slice actually changed since the last share
/// (the dirty guard that keeps the literal share-on-receive rule from
/// echoing forever once converged) — and decay `T_k ← T_k/α` only on a
/// real crossing with progress, so a converged PID spinning at
/// `r_k = 0 < T_k` cannot drive its threshold toward zero and its share
/// rate toward infinity. Returns whether to share; the caller clears its
/// dirty bit after a share. This is the edge of the seed V1 scheme the
/// `RebaseMode::Local` streaming protocol builds on, extracted so it is
/// unit-testable.
pub fn share_and_decay(
    r_k: f64,
    threshold: &mut f64,
    alpha: f64,
    got_update: bool,
    dirty: bool,
) -> bool {
    let threshold_hit = r_k < *threshold;
    if threshold_hit && dirty {
        *threshold /= alpha; // §4.1 (only on real progress)
    }
    (threshold_hit || got_update) && dirty
}

/// Solve with the V1 scheme. The partition in `cfg` must cover the
/// problem's coordinates.
pub fn solve_v1(
    problem: &FixedPointProblem,
    cfg: &DistributedConfig,
) -> Result<DistributedSolution> {
    let n = problem.n();
    if cfg.partition.n() != n {
        return Err(DiterError::shape("solve_v1 partition", n, cfg.partition.n()));
    }
    let k = cfg.partition.k();
    let state = MonitorState::new(k);
    let (endpoints, bus_metrics) = bus::<SliceMsg>(
        k,
        &BusConfig {
            latency: cfg.latency,
            seed: cfg.seed,
            flush: cfg.wire_flush,
            ack_release: false,
        },
    );
    let bus_mon = monitor_of(&endpoints[0]);
    let problem = Arc::new(problem.clone());
    let partition = Arc::new(cfg.partition.clone());

    let mut handles = Vec::with_capacity(k);
    for (kk, ep) in endpoints.into_iter().enumerate() {
        let problem = problem.clone();
        let partition = partition.clone();
        let state = state.clone();
        let cfg = cfg.clone();
        handles.push(std::thread::spawn(move || {
            v1_worker(kk, ep, &problem, &partition, &state, &cfg)
        }));
    }

    let (converged_mon, trace, wall) = run_monitor(
        &state,
        &bus_mon,
        n,
        cfg.tol,
        cfg.max_wall,
        Duration::from_micros(200),
        3,
    );

    // collect final slices
    let mut x = vec![0.0; n];
    for h in handles {
        let (owned, values) = h
            .join()
            .map_err(|_| DiterError::Coordinator("V1 worker panicked".into()))?;
        for (t, &i) in owned.iter().enumerate() {
            x[i] = values[t];
        }
    }
    let residual = problem.residual_norm(&x);
    Ok(DistributedSolution {
        residual,
        converged: converged_mon && residual <= cfg.tol * 10.0,
        cost: state.max_updates() as f64 / n as f64,
        total_updates: state.total_updates(),
        wall_secs: wall,
        trace: relabel(trace, "v1-total-fluid"),
        metrics: bus_metrics.snapshot(),
        x,
    })
}

fn relabel(mut t: ConvergenceTrace, name: &str) -> ConvergenceTrace {
    t.name = name.to_string();
    t
}

/// One PID's work loop. Returns (owned indices, final owned values).
fn v1_worker(
    k: usize,
    mut ep: Endpoint<SliceMsg>,
    problem: &FixedPointProblem,
    partition: &crate::partition::Partition,
    state: &MonitorState,
    cfg: &DistributedConfig,
) -> (Vec<usize>, Vec<f64>) {
    let csr = problem.matrix().csr();
    let b = problem.b();
    let owned: Vec<usize> = partition.part(k).to_vec();
    // §2.1.1: start from H = B (the free first sweep)
    let mut h: Vec<f64> = b.to_vec();
    let mut seq = SequenceState::new(
        cfg.sequence,
        owned.clone(),
        cfg.seed ^ (k as u64).wrapping_mul(0x9E3779B97F4A7C15),
    );
    let mut threshold = cfg.threshold0;
    let mut dirty = true; // slice changed since last share
    let empty_fluid: Vec<f64> = Vec::new();
    // greedy sequences need a live fluid view over owned coordinates
    let use_greedy = cfg.sequence == crate::solver::SequenceKind::GreedyMaxFluid;
    let mut fluid: Vec<f64> = if use_greedy { problem.fluid(&h) } else { empty_fluid };

    loop {
        if state.should_stop() {
            break;
        }
        // 1. apply peer updates (uncommitted: the messages stay on the
        //    bus's undelivered count until applied + republished, so the
        //    monitor cannot declare quiescence in between)
        let received = ep.drain_uncommitted();
        let got_update = !received.is_empty();
        for msg in &received {
            let peer_owned = partition.part(msg.payload.owner);
            for (t, &i) in peer_owned.iter().enumerate() {
                h[i] = msg.payload.values[t];
            }
        }
        if got_update && use_greedy {
            fluid = problem.fluid(&h); // peer writes invalidate the view
        }
        if got_update {
            // publish the post-apply r_k before committing receipt
            let mut r = 0.0;
            for &i in &owned {
                r += (csr.row_dot(i, &h) + b[i] - h[i]).abs();
            }
            state.publish(k, r);
            for msg in &received {
                ep.commit(msg.from, msg.seq, msg.mass);
            }
        }
        // 2. local updates (eq. 6): sweeps_per_round passes over Ω_k
        let quanta = cfg.sweeps_per_round * owned.len();
        for _ in 0..quanta {
            let i = seq.next(&fluid);
            let new = csr.row_dot(i, &h) + b[i];
            if new != h[i] {
                dirty = true;
            }
            if use_greedy {
                let delta = new - h[i];
                h[i] = new;
                fluid[i] = 0.0;
                if delta != 0.0 {
                    let (rows, vals) = problem.matrix().csc().col(i);
                    for t in 0..rows.len() {
                        fluid[rows[t]] += vals[t] * delta;
                    }
                }
            } else {
                h[i] = new;
            }
        }
        state.add_updates(k, quanta as u64);
        // 3. local remaining fluid (§4.1)
        let mut r_k = 0.0;
        for &i in &owned {
            r_k += (csr.row_dot(i, &h) + b[i] - h[i]).abs();
        }
        state.publish(k, r_k);
        // 4. sharing triggers (§4.3)
        if share_and_decay(r_k, &mut threshold, cfg.threshold_alpha, got_update, dirty) {
            let values: Vec<f64> = owned.iter().map(|&i| h[i]).collect();
            let bytes = values.len() * 8 + 16;
            let _ = ep.broadcast(
                &SliceMsg {
                    owner: k,
                    values,
                },
                0.0, // V1 messages carry state, not fluid mass
                bytes,
            );
            dirty = false;
        }
        // 5. idle backoff: nothing new and locally converged
        if !got_update && r_k == 0.0 {
            std::thread::sleep(Duration::from_micros(50));
        }
    }
    ep.collect_acks();
    let values: Vec<f64> = owned.iter().map(|&i| h[i]).collect();
    (owned, values)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::paper_matrix;
    use crate::linalg::vec_ops::dist_inf;
    use crate::partition::Partition;
    use crate::solver::SequenceKind;

    fn a1_problem() -> FixedPointProblem {
        FixedPointProblem::from_linear_system(&paper_matrix(1), &[1.0; 4]).unwrap()
    }

    #[test]
    fn two_pids_solve_a1() {
        let problem = a1_problem();
        let cfg = DistributedConfig::new(Partition::contiguous(4, 2).unwrap()).with_tol(1e-12);
        let sol = solve_v1(&problem, &cfg).unwrap();
        assert!(sol.converged, "residual {}", sol.residual);
        let exact = problem.exact_solution().unwrap();
        assert!(dist_inf(&sol.x, &exact) < 1e-9);
        assert!(sol.total_updates > 0);
    }

    #[test]
    fn four_pids_with_coupling() {
        let problem =
            FixedPointProblem::from_linear_system(&paper_matrix(3), &[1.0; 4]).unwrap();
        let cfg = DistributedConfig::new(Partition::contiguous(4, 4).unwrap()).with_tol(1e-11);
        let sol = solve_v1(&problem, &cfg).unwrap();
        assert!(sol.converged);
        let exact = problem.exact_solution().unwrap();
        assert!(dist_inf(&sol.x, &exact) < 1e-8);
    }

    #[test]
    fn single_pid_degenerates_to_sequential() {
        let problem = a1_problem();
        let cfg = DistributedConfig::new(Partition::contiguous(4, 1).unwrap()).with_tol(1e-12);
        let sol = solve_v1(&problem, &cfg).unwrap();
        assert!(sol.converged);
        assert!(sol.metrics["msgs_sent"] == 0, "no peers, no messages");
    }

    #[test]
    fn greedy_sequence_works_distributed() {
        let problem =
            FixedPointProblem::from_linear_system(&paper_matrix(2), &[1.0; 4]).unwrap();
        let cfg = DistributedConfig::new(Partition::contiguous(4, 2).unwrap())
            .with_tol(1e-11)
            .with_sequence(SequenceKind::GreedyMaxFluid);
        let sol = solve_v1(&problem, &cfg).unwrap();
        assert!(sol.converged);
        let exact = problem.exact_solution().unwrap();
        assert!(dist_inf(&sol.x, &exact) < 1e-8);
    }

    #[test]
    fn latency_does_not_break_convergence() {
        let problem =
            FixedPointProblem::from_linear_system(&paper_matrix(2), &[1.0; 4]).unwrap();
        let mut cfg =
            DistributedConfig::new(Partition::contiguous(4, 2).unwrap()).with_tol(1e-11);
        cfg.latency = Some((Duration::from_micros(100), Duration::from_micros(500)));
        let sol = solve_v1(&problem, &cfg).unwrap();
        assert!(sol.converged);
    }

    #[test]
    fn partition_size_mismatch_rejected() {
        let problem = a1_problem();
        let cfg = DistributedConfig::new(Partition::contiguous(6, 2).unwrap());
        assert!(solve_v1(&problem, &cfg).is_err());
    }

    #[test]
    fn threshold_decays_geometrically_on_real_crossings() {
        // §4.1: T_k ← T_k/α exactly once per crossing round with progress
        let mut t = 1.0;
        for round in 1..=5 {
            assert!(share_and_decay(1e-6, &mut t, 2.0, false, true));
            assert!((t - 1.0 / 2.0f64.powi(round)).abs() < 1e-15, "round {round}: T = {t}");
        }
        // a different α divides by that α
        let mut t = 8.0;
        assert!(share_and_decay(0.0, &mut t, 4.0, false, true));
        assert_eq!(t, 2.0);
    }

    #[test]
    fn threshold_never_decays_without_progress() {
        // a converged PID (clean slice) spinning at r_k < T_k must not
        // drive T_k to zero — the decay is gated on the dirty bit
        let mut t = 1e-3;
        for _ in 0..100 {
            assert!(!share_and_decay(0.0, &mut t, 2.0, false, false));
            assert!(!share_and_decay(0.0, &mut t, 2.0, true, false));
        }
        assert_eq!(t, 1e-3, "threshold untouched without progress");
        // and never decays while above the threshold, dirty or not
        let mut t = 1e-3;
        assert!(!share_and_decay(1.0, &mut t, 2.0, false, true));
        assert_eq!(t, 1e-3);
    }

    #[test]
    fn dirty_guard_blocks_the_share_on_receive_echo() {
        // the literal §4.3 rule ("share when you receive") echoes forever
        // between converged PIDs; the dirty guard is what breaks the loop
        let mut t = 1.0;
        assert!(share_and_decay(0.5, &mut t, 2.0, true, true), "peer update + progress");
        assert_eq!(t, 1.0, "no crossing, no decay");
        assert!(
            !share_and_decay(0.5, &mut t, 2.0, true, false),
            "peer update without progress: suppressed"
        );
        assert!(
            !share_and_decay(0.5, &mut t, 2.0, false, true),
            "no trigger at all above the threshold"
        );
    }
}
