//! Deterministic lockstep simulator of the distributed schemes — the
//! engine behind the paper-figure benches (Fig 1–4).
//!
//! The threaded runtime ([`super::v1`], [`super::v2`]) is asynchronous and
//! therefore not run-to-run reproducible; the figures need the *exact*
//! protocol of §5.1: "we applied jointly the cyclical sequence {1,2} and
//! {3,4} exactly twice before sharing the local computation results".
//! This module executes that protocol round-by-round: each round every PID
//! performs `sweeps_per_share` local cyclic sweeps on its own full-H copy
//! (V1 semantics), then all PIDs exchange slices simultaneously.
//!
//! Cost convention: each sweep costs 1 unit of *parallel* time (all PIDs
//! sweep concurrently; a sweep touches |Ω_k| ≈ N/K coordinates, i.e. the
//! per-PID work per unit is 1/K of the sequential method's — that is
//! exactly the "gain factor of about 2 with 2 PIDs" of Fig 1).
//!
//! Snapshots of the assembled solution (owner's view of each coordinate)
//! are recorded after every sweep so benches can chart any error measure.

use crate::error::Result;
use crate::partition::Partition;
use crate::solver::{FixedPointProblem, Solver};

/// A cost-stamped snapshot of the assembled solution.
#[derive(Clone, Debug)]
pub struct Snapshot {
    pub cost: f64,
    pub x: Vec<f64>,
}

/// Lockstep V1 run configuration.
#[derive(Clone, Debug)]
pub struct SimConfig {
    pub partition: Partition,
    /// local sweeps between simultaneous shares (paper Fig 1: 2)
    pub sweeps_per_share: usize,
    /// total parallel cost units to run
    pub max_cost: usize,
    /// optionally switch to a new system once cumulative cost reaches
    /// `.0` (the paper's §5.2 switches "from iteration 6")
    pub switch_at: Option<(usize, FixedPointProblem)>,
}

/// Run the lockstep V1 distributed D-iteration; returns one snapshot per
/// parallel cost unit (sweep), starting with the initial state at cost 0.
pub fn simulate_v1(problem: &FixedPointProblem, cfg: &SimConfig) -> Result<Vec<Snapshot>> {
    let n = problem.n();
    let k = cfg.partition.k();
    let mut current: FixedPointProblem = problem.clone();
    // every PID holds a full H, initialized to B (§2.1.1)
    let mut hs: Vec<Vec<f64>> = vec![current.b().to_vec(); k];
    let mut snaps = Vec::with_capacity(cfg.max_cost + 1);
    snaps.push(Snapshot {
        cost: 0.0,
        x: assemble(&cfg.partition, &hs, n),
    });
    let mut cost = 0usize;
    while cost < cfg.max_cost {
        // §3.2 live switch: matrix changes, warm H kept (H-form needs no
        // rebase — eq. 6 converges to the new limit from any start).
        if let Some((at, new_problem)) = &cfg.switch_at {
            if cost == *at {
                current = new_problem.clone();
            }
        }
        // one round = sweeps_per_share sweeps then a share
        for _ in 0..cfg.sweeps_per_share {
            if cost >= cfg.max_cost {
                break;
            }
            for (kk, h) in hs.iter_mut().enumerate() {
                let csr = current.matrix().csr();
                for &i in cfg.partition.part(kk) {
                    h[i] = csr.row_dot(i, h) + current.b()[i];
                }
            }
            cost += 1;
            snaps.push(Snapshot {
                cost: cost as f64,
                x: assemble(&cfg.partition, &hs, n),
            });
        }
        // simultaneous exchange: everyone receives everyone's slice
        let assembled = assemble(&cfg.partition, &hs, n);
        for h in hs.iter_mut() {
            h.copy_from_slice(&assembled);
        }
    }
    Ok(snaps)
}

/// Assemble the owners' view: coordinate i comes from its owner's H.
fn assemble(partition: &Partition, hs: &[Vec<f64>], n: usize) -> Vec<f64> {
    let mut x = vec![0.0; n];
    for kk in 0..partition.k() {
        for &i in partition.part(kk) {
            x[i] = hs[kk][i];
        }
    }
    x
}

/// Snapshot runner for any sequential [`Solver`]: records the solution
/// after every cost unit by re-running with growing budgets (small-N
/// figure harnesses only — O(max_cost²) but N = 4).
pub fn sequential_snapshots(
    solver: &dyn Solver,
    problem: &FixedPointProblem,
    max_cost: usize,
    switch_at: Option<(usize, &FixedPointProblem)>,
) -> Result<Vec<Snapshot>> {
    let mut snaps = Vec::with_capacity(max_cost + 1);
    for budget in 0..=max_cost {
        let x = run_with_budget(solver, problem, budget, switch_at)?;
        snaps.push(Snapshot {
            cost: budget as f64,
            x,
        });
    }
    Ok(snaps)
}

fn run_with_budget(
    solver: &dyn Solver,
    problem: &FixedPointProblem,
    budget: usize,
    switch_at: Option<(usize, &FixedPointProblem)>,
) -> Result<Vec<f64>> {
    use crate::solver::SolveOptions;
    let opts_for = |cost: usize| SolveOptions {
        tol: 0.0,
        max_cost: cost as f64,
        trace_every: 0.0,
        exact: None,
    };
    match switch_at {
        None => Ok(solver.solve(problem, &opts_for(budget))?.x),
        Some((at, _new_problem)) if budget <= at => {
            Ok(solver.solve(problem, &opts_for(budget))?.x)
        }
        Some((at, new_problem)) => {
            // warm-start continuation on the new system: rebase B' so the
            // fluid/history split stays consistent (§3.2), then finish.
            let h = solver.solve(problem, &opts_for(at))?.x;
            let b_prime = super::update::rebase_b(new_problem.matrix(), &h, new_problem.b())?;
            let sub = FixedPointProblem::new(new_problem.matrix().clone(), b_prime)?;
            let y = solver.solve(&sub, &opts_for(budget - at))?.x;
            Ok(h.iter().zip(&y).map(|(a, b)| a + b).collect())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::paper_matrix;
    use crate::linalg::vec_ops::dist1;
    use crate::solver::{DIteration, GaussSeidel, Jacobi};

    fn problem(which: u8) -> FixedPointProblem {
        FixedPointProblem::from_linear_system(&paper_matrix(which), &[1.0; 4]).unwrap()
    }

    fn paper_cfg(max_cost: usize) -> SimConfig {
        SimConfig {
            partition: Partition::contiguous(4, 2).unwrap(),
            sweeps_per_share: 2,
            max_cost,
            switch_at: None,
        }
    }

    #[test]
    fn lockstep_converges_to_exact_a1() {
        let p = problem(1);
        let snaps = simulate_v1(&p, &paper_cfg(40)).unwrap();
        let exact = p.exact_solution().unwrap();
        let last = snaps.last().unwrap();
        assert!(dist1(&last.x, &exact) < 1e-12);
        assert_eq!(snaps.len(), 41);
    }

    #[test]
    fn a1_gain_factor_about_two() {
        // Fig 1's claim: with no coupling, the 2-PID run reaches a given
        // error in about half the parallel cost of the 1-PID run.
        let p = problem(1);
        let exact = p.exact_solution().unwrap();
        let two = simulate_v1(&p, &paper_cfg(60)).unwrap();
        let one = simulate_v1(
            &p,
            &SimConfig {
                partition: Partition::contiguous(4, 1).unwrap(),
                sweeps_per_share: 2,
                max_cost: 60,
                switch_at: None,
            },
        )
        .unwrap();
        let reach = |snaps: &[Snapshot], tol: f64| {
            snaps
                .iter()
                .find(|s| dist1(&s.x, &exact) < tol)
                .map(|s| s.cost)
        };
        let tol = 1e-8;
        let c2 = reach(&two, tol).expect("2-PID must reach tol");
        let c1 = reach(&one, tol).expect("1-PID must reach tol");
        // each 2-PID sweep does half the scalar updates, so per-update the
        // runs match; per *parallel cost* the distributed one wins ≈2×.
        // (cost axis counts sweeps, and sweeps are half as much work —
        // verify the speedup in equivalent-work units: c2 ≈ c1.)
        // In parallel wall-time (sweeps), equal sweep counts mean the
        // distributed run used half the per-PID work: gain ≈ c1*2/c2 ≈ 2.
        let gain = 2.0 * c1 / c2.max(1.0);
        assert!(
            (1.5..=3.0).contains(&gain),
            "gain {gain} (c1={c1}, c2={c2})"
        );
    }

    #[test]
    fn a3_coupling_kills_gain() {
        // Fig 3: with strong coupling the 2-PID lockstep needs ~as many
        // parallel sweeps as the sequential run (no significant gain).
        let p = problem(3);
        let exact = p.exact_solution().unwrap();
        let two = simulate_v1(&p, &paper_cfg(200)).unwrap();
        let tol = 1e-8;
        let c2 = two
            .iter()
            .find(|s| dist1(&s.x, &exact) < tol)
            .map(|s| s.cost)
            .expect("still converges");
        // sequential D-iteration cost for the same tol
        let seq = sequential_snapshots(&DIteration::cyclic(), &p, 200, None).unwrap();
        let c1 = seq
            .iter()
            .find(|s| dist1(&s.x, &exact) < tol)
            .map(|s| s.cost)
            .unwrap();
        let gain = 2.0 * c1 / c2.max(1.0);
        assert!(gain < 1.8, "gain should collapse, got {gain}");
    }

    #[test]
    fn sequential_snapshot_matches_direct_solver_run() {
        let p = problem(2);
        let snaps = sequential_snapshots(&GaussSeidel::new(), &p, 10, None).unwrap();
        assert_eq!(snaps.len(), 11);
        // snapshots are reproducible and improving
        let exact = p.exact_solution().unwrap();
        let e_first = dist1(&snaps[1].x, &exact);
        let e_last = dist1(&snaps[10].x, &exact);
        assert!(e_last < e_first);
    }

    #[test]
    fn switch_mid_run_reaches_new_limit() {
        // the §5.2 scenario as a lockstep sim
        let p_old = problem(1);
        let p_new = problem(4);
        let cfg = SimConfig {
            partition: Partition::contiguous(4, 2).unwrap(),
            sweeps_per_share: 2,
            max_cost: 80,
            switch_at: Some((6, p_new.clone())),
        };
        let snaps = simulate_v1(&p_old, &cfg).unwrap();
        let exact_new = p_new.exact_solution().unwrap();
        let last = snaps.last().unwrap();
        assert!(
            dist1(&last.x, &exact_new) < 1e-10,
            "dist {}",
            dist1(&last.x, &exact_new)
        );
    }

    #[test]
    fn sequential_switch_runner_consistent() {
        let p_old = problem(1);
        let p_new = problem(4);
        let snaps =
            sequential_snapshots(&Jacobi::new(), &p_old, 120, Some((6, &p_new))).unwrap();
        let exact_new = p_new.exact_solution().unwrap();
        assert!(dist1(&snaps.last().unwrap().x, &exact_new) < 1e-8);
    }
}
