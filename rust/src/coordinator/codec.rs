//! Wire encoding of [`WorkerMsg`] (DESIGN.md §8.3): what a fluid parcel,
//! an ownership handoff, and a halo slice look like as bytes.
//!
//! Layout principles, in order of importance:
//!
//! * **SoA stays SoA** — a `Fluid` parcel's mass column is one bulk
//!   little-endian `f64` copy; nothing is interleaved per entry;
//! * **coordinate columns are delta-encoded** — workers emit coalesced
//!   parcels with ascending coordinates, so the zigzag-varint delta
//!   column costs ~1 byte per coordinate instead of 4–8;
//! * **explicit epoch tags** — every payload carries the epoch (and a
//!   handoff its ownership version) so receivers can stash/foster
//!   exactly as they do in-process; the wire adds reordering and delay,
//!   never ambiguity;
//! * **strict decode** — trailing bytes, truncation, or a count that
//!   cannot fit the frame are errors that kill the connection, not
//!   best-effort data.
//!
//! **Query-lane extension (DESIGN.md §10.4).** Multi-RHS serving adds
//! three payload tags, chosen so a single-query engine's bytes are
//! *identical* to the pre-lane format:
//!
//! * `0x13 FLUID_MQ` — a fluid parcel whose entries target more than
//!   one query lane: the `0x10` layout plus a trailing `qids` column
//!   (zigzag-varint deltas, one **global query id** per entry). A
//!   parcel whose entries are all lane 0 always encodes as plain
//!   `0x10` with no column;
//! * `0x14 HANDOFF_ML` / `0x15 HALO_ML` — the `0x11`/`0x12` layouts
//!   plus a `lanes` varint (≥ 2) after the count; the `h` (and for a
//!   handoff `f`) columns are lane-blocked, `count*lanes` long, while
//!   `b` stays `count` (the base problem owns the only static source
//!   term — query seeds travel through the registry, not the wire).
//!   Encode infers the lane count from the column shape, so `lanes ==
//!   1` engines emit the plain tags unconditionally.

use crate::coordinator::worker::{Handoff, WorkerMsg};
use crate::error::Result;
use crate::transport::wire::{
    corrupt, read_deltas, read_deltas_u32_into, read_deltas_usize_into, read_f64_slice,
    read_f64_slice_into, read_varint, write_deltas, write_f64_slice, write_varint, ColumnPools,
    WireCodec,
};

/// Payload tag of [`WorkerMsg::Fluid`] with every entry on lane 0.
pub const TAG_FLUID: u8 = 0x10;
/// Payload tag of [`WorkerMsg::Handoff`] with single-lane columns.
pub const TAG_HANDOFF: u8 = 0x11;
/// Payload tag of [`WorkerMsg::HaloSlice`] with a single-lane column.
pub const TAG_HALO: u8 = 0x12;
/// Payload tag of [`WorkerMsg::Fluid`] carrying a `qids` column.
pub const TAG_FLUID_MQ: u8 = 0x13;
/// Payload tag of [`WorkerMsg::Handoff`] with lane-blocked `h`/`f`.
pub const TAG_HANDOFF_ML: u8 = 0x14;
/// Payload tag of [`WorkerMsg::HaloSlice`] with a lane-blocked `h`.
pub const TAG_HALO_ML: u8 = 0x15;

fn coords_u32(raw: Vec<u64>) -> Result<Vec<u32>> {
    raw.into_iter()
        .map(|v| u32::try_from(v).map_err(|_| corrupt("coordinate exceeds u32")))
        .collect()
}

/// Lane count implied by a lane-blocked column over `count` coordinates
/// (1 for an empty slice: an empty message has no lane structure).
fn infer_lanes(count: usize, blocked_len: usize) -> usize {
    if count == 0 {
        1
    } else {
        debug_assert_eq!(blocked_len % count, 0, "column is not lane-blocked");
        blocked_len / count
    }
}

/// Read and validate the `lanes` varint of a `*_ML` payload, returning
/// `(lanes, count*lanes)`. Plain tags are the canonical encoding for a
/// single lane, so `lanes < 2` is a corrupt frame, as is a blocked
/// column too large to index.
fn read_lanes(buf: &[u8], pos: &mut usize, count: usize) -> Result<(usize, usize)> {
    let lanes = read_varint(buf, pos)? as usize;
    if lanes < 2 {
        return Err(corrupt("multi-lane payload with lanes < 2"));
    }
    let wide = count
        .checked_mul(lanes)
        .ok_or_else(|| corrupt("lane-blocked column length overflows"))?;
    Ok((lanes, wide))
}

impl WireCodec for WorkerMsg {
    fn encode(&self, out: &mut Vec<u8>) {
        match self {
            WorkerMsg::Fluid {
                epoch,
                coords,
                mass,
                qids,
            } => {
                debug_assert_eq!(coords.len(), mass.len());
                debug_assert!(qids.is_empty() || qids.len() == coords.len());
                out.push(if qids.is_empty() {
                    TAG_FLUID
                } else {
                    TAG_FLUID_MQ
                });
                write_varint(out, *epoch);
                write_varint(out, coords.len() as u64);
                write_deltas(out, coords.iter().map(|&c| u64::from(c)));
                write_f64_slice(out, mass);
                if !qids.is_empty() {
                    write_deltas(out, qids.iter().map(|&q| u64::from(q)));
                }
            }
            WorkerMsg::Handoff(ho) => {
                let count = ho.coords.len();
                let lanes = infer_lanes(count, ho.h_slice.len());
                debug_assert_eq!(ho.h_slice.len(), count * lanes);
                debug_assert_eq!(ho.b_slice.len(), count);
                debug_assert_eq!(ho.f_slice.len(), count * lanes);
                out.push(if lanes == 1 { TAG_HANDOFF } else { TAG_HANDOFF_ML });
                write_varint(out, ho.pid_from as u64);
                write_varint(out, ho.pid_to as u64);
                write_varint(out, ho.version);
                write_varint(out, ho.epoch);
                write_varint(out, count as u64);
                if lanes > 1 {
                    write_varint(out, lanes as u64);
                }
                write_deltas(out, ho.coords.iter().map(|&c| c as u64));
                write_f64_slice(out, &ho.h_slice);
                write_f64_slice(out, &ho.b_slice);
                write_f64_slice(out, &ho.f_slice);
            }
            WorkerMsg::HaloSlice { epoch, coords, h } => {
                let count = coords.len();
                let lanes = infer_lanes(count, h.len());
                debug_assert_eq!(h.len(), count * lanes);
                out.push(if lanes == 1 { TAG_HALO } else { TAG_HALO_ML });
                write_varint(out, *epoch);
                write_varint(out, count as u64);
                if lanes > 1 {
                    write_varint(out, lanes as u64);
                }
                write_deltas(out, coords.iter().map(|&c| u64::from(c)));
                write_f64_slice(out, h);
            }
        }
    }

    fn decode(buf: &[u8]) -> Result<WorkerMsg> {
        let Some(&tag) = buf.first() else {
            return Err(corrupt("empty payload"));
        };
        let mut pos = 1;
        let msg = match tag {
            TAG_FLUID | TAG_FLUID_MQ => {
                let epoch = read_varint(buf, &mut pos)?;
                let count = read_varint(buf, &mut pos)? as usize;
                let coords = coords_u32(read_deltas(buf, &mut pos, count)?)?;
                let mass = read_f64_slice(buf, &mut pos, count)?;
                let qids = if tag == TAG_FLUID_MQ {
                    coords_u32(read_deltas(buf, &mut pos, count)?)?
                } else {
                    Vec::new()
                };
                WorkerMsg::Fluid {
                    epoch,
                    coords,
                    mass,
                    qids,
                }
            }
            TAG_HANDOFF | TAG_HANDOFF_ML => {
                let pid_from = read_varint(buf, &mut pos)? as usize;
                let pid_to = read_varint(buf, &mut pos)? as usize;
                let version = read_varint(buf, &mut pos)?;
                let epoch = read_varint(buf, &mut pos)?;
                let count = read_varint(buf, &mut pos)? as usize;
                let wide = if tag == TAG_HANDOFF_ML {
                    read_lanes(buf, &mut pos, count)?.1
                } else {
                    count
                };
                let coords = read_deltas(buf, &mut pos, count)?
                    .into_iter()
                    .map(|v| v as usize)
                    .collect();
                let h_slice = read_f64_slice(buf, &mut pos, wide)?;
                let b_slice = read_f64_slice(buf, &mut pos, count)?;
                let f_slice = read_f64_slice(buf, &mut pos, wide)?;
                WorkerMsg::Handoff(Handoff {
                    pid_from,
                    pid_to,
                    version,
                    epoch,
                    coords,
                    h_slice,
                    b_slice,
                    f_slice,
                })
            }
            TAG_HALO | TAG_HALO_ML => {
                let epoch = read_varint(buf, &mut pos)?;
                let count = read_varint(buf, &mut pos)? as usize;
                let wide = if tag == TAG_HALO_ML {
                    read_lanes(buf, &mut pos, count)?.1
                } else {
                    count
                };
                let coords = coords_u32(read_deltas(buf, &mut pos, count)?)?;
                let h = read_f64_slice(buf, &mut pos, wide)?;
                WorkerMsg::HaloSlice { epoch, coords, h }
            }
            other => return Err(corrupt(&format!("unknown payload tag {other:#04x}"))),
        };
        if pos != buf.len() {
            return Err(corrupt("trailing bytes after payload"));
        }
        Ok(msg)
    }

    /// [`WireCodec::decode`] with every column vector drawn from `pools`
    /// instead of the allocator — the wire receive path's steady state.
    /// Decodes exactly the same values as `decode` (the codec tests pin
    /// the equivalence); on any decode error the storage taken so far
    /// goes straight back to the pools.
    fn decode_pooled(buf: &[u8], pools: &mut ColumnPools) -> Result<WorkerMsg> {
        let Some(&tag) = buf.first() else {
            return Err(corrupt("empty payload"));
        };
        let mut pos = 1;
        let msg = match tag {
            TAG_FLUID | TAG_FLUID_MQ => {
                let epoch = read_varint(buf, &mut pos)?;
                let count = read_varint(buf, &mut pos)? as usize;
                let mut coords = pools.u32s.take();
                let mut mass = pools.f64s.take();
                let mut qids = pools.u32s.take();
                let cols = read_deltas_u32_into(buf, &mut pos, count, &mut coords)
                    .and_then(|()| read_f64_slice_into(buf, &mut pos, count, &mut mass))
                    .and_then(|()| {
                        if tag == TAG_FLUID_MQ {
                            read_deltas_u32_into(buf, &mut pos, count, &mut qids)
                        } else {
                            Ok(())
                        }
                    });
                if let Err(e) = cols {
                    pools.u32s.give(coords);
                    pools.f64s.give(mass);
                    pools.u32s.give(qids);
                    return Err(e);
                }
                WorkerMsg::Fluid {
                    epoch,
                    coords,
                    mass,
                    qids,
                }
            }
            TAG_HANDOFF | TAG_HANDOFF_ML => {
                let pid_from = read_varint(buf, &mut pos)? as usize;
                let pid_to = read_varint(buf, &mut pos)? as usize;
                let version = read_varint(buf, &mut pos)?;
                let epoch = read_varint(buf, &mut pos)?;
                let count = read_varint(buf, &mut pos)? as usize;
                let wide = if tag == TAG_HANDOFF_ML {
                    match read_lanes(buf, &mut pos, count) {
                        Ok((_, w)) => w,
                        Err(e) => return Err(e),
                    }
                } else {
                    count
                };
                let mut coords = pools.usizes.take();
                let mut h_slice = pools.f64s.take();
                let mut b_slice = pools.f64s.take();
                let mut f_slice = pools.f64s.take();
                let cols = read_deltas_usize_into(buf, &mut pos, count, &mut coords)
                    .and_then(|()| read_f64_slice_into(buf, &mut pos, wide, &mut h_slice))
                    .and_then(|()| read_f64_slice_into(buf, &mut pos, count, &mut b_slice))
                    .and_then(|()| read_f64_slice_into(buf, &mut pos, wide, &mut f_slice));
                if let Err(e) = cols {
                    pools.usizes.give(coords);
                    pools.f64s.give(h_slice);
                    pools.f64s.give(b_slice);
                    pools.f64s.give(f_slice);
                    return Err(e);
                }
                WorkerMsg::Handoff(Handoff {
                    pid_from,
                    pid_to,
                    version,
                    epoch,
                    coords,
                    h_slice,
                    b_slice,
                    f_slice,
                })
            }
            TAG_HALO | TAG_HALO_ML => {
                let epoch = read_varint(buf, &mut pos)?;
                let count = read_varint(buf, &mut pos)? as usize;
                let wide = if tag == TAG_HALO_ML {
                    match read_lanes(buf, &mut pos, count) {
                        Ok((_, w)) => w,
                        Err(e) => return Err(e),
                    }
                } else {
                    count
                };
                let mut coords = pools.u32s.take();
                let mut h = pools.f64s.take();
                let cols = read_deltas_u32_into(buf, &mut pos, count, &mut coords)
                    .and_then(|()| read_f64_slice_into(buf, &mut pos, wide, &mut h));
                if let Err(e) = cols {
                    pools.u32s.give(coords);
                    pools.f64s.give(h);
                    return Err(e);
                }
                WorkerMsg::HaloSlice { epoch, coords, h }
            }
            other => return Err(corrupt(&format!("unknown payload tag {other:#04x}"))),
        };
        if pos != buf.len() {
            msg.reclaim(pools);
            return Err(corrupt("trailing bytes after payload"));
        }
        Ok(msg)
    }

    /// Return every column vector to `pools` — called by the wire send
    /// path after the payload has been encoded into its frame, closing
    /// the storage cycle (decode → worker → coalesce → encode → pools).
    fn reclaim(self, pools: &mut ColumnPools) {
        match self {
            WorkerMsg::Fluid {
                coords, mass, qids, ..
            } => {
                pools.u32s.give(coords);
                pools.f64s.give(mass);
                pools.u32s.give(qids);
            }
            WorkerMsg::Handoff(ho) => {
                pools.usizes.give(ho.coords);
                pools.f64s.give(ho.h_slice);
                pools.f64s.give(ho.b_slice);
                pools.f64s.give(ho.f_slice);
            }
            WorkerMsg::HaloSlice { coords, h, .. } => {
                pools.u32s.give(coords);
                pools.f64s.give(h);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn round_trip(msg: &WorkerMsg) -> WorkerMsg {
        let mut buf = Vec::new();
        msg.encode(&mut buf);
        WorkerMsg::decode(&buf).expect("decode what we encoded")
    }

    #[test]
    fn fluid_round_trip() {
        let msg = WorkerMsg::Fluid {
            epoch: 3,
            coords: vec![1, 5, 6, 900],
            mass: vec![0.25, -0.5, 1e-17, 3.75],
            qids: vec![],
        };
        assert_eq!(round_trip(&msg), msg);
    }

    #[test]
    fn empty_fluid_round_trip() {
        let msg = WorkerMsg::Fluid {
            epoch: 0,
            coords: vec![],
            mass: vec![],
            qids: vec![],
        };
        assert_eq!(round_trip(&msg), msg);
    }

    #[test]
    fn multi_query_fluid_round_trip() {
        let msg = WorkerMsg::Fluid {
            epoch: 5,
            coords: vec![1, 1, 7, 900],
            mass: vec![0.25, -0.5, 1e-17, 3.75],
            qids: vec![0, 3, 3, 17],
        };
        let mut buf = Vec::new();
        msg.encode(&mut buf);
        assert_eq!(buf[0], TAG_FLUID_MQ);
        assert_eq!(WorkerMsg::decode(&buf).unwrap(), msg);
    }

    #[test]
    fn lane_zero_fluid_keeps_the_pre_lane_bytes() {
        // the qids column is shape, not data: an all-lane-0 parcel must
        // encode byte-identically to the historical 0x10 layout
        let msg = WorkerMsg::Fluid {
            epoch: 3,
            coords: vec![1, 5, 6],
            mass: vec![0.25, -0.5, 0.125],
            qids: vec![],
        };
        let mut buf = Vec::new();
        msg.encode(&mut buf);
        assert_eq!(buf[0], TAG_FLUID);
        let mut legacy = vec![TAG_FLUID];
        write_varint(&mut legacy, 3);
        write_varint(&mut legacy, 3);
        write_deltas(&mut legacy, [1u64, 5, 6]);
        write_f64_slice(&mut legacy, &[0.25, -0.5, 0.125]);
        assert_eq!(buf, legacy);
    }

    #[test]
    fn handoff_round_trip() {
        let msg = WorkerMsg::Handoff(Handoff {
            pid_from: 2,
            pid_to: 0,
            version: 7,
            epoch: 4,
            coords: vec![10, 11, 12],
            h_slice: vec![0.1, 0.2, 0.3],
            b_slice: vec![1.0, 0.0, -1.0],
            f_slice: vec![1e-9, 0.5, 0.0],
        });
        let mut buf = Vec::new();
        msg.encode(&mut buf);
        assert_eq!(buf[0], TAG_HANDOFF, "single-lane columns use the plain tag");
        assert_eq!(WorkerMsg::decode(&buf).unwrap(), msg);
    }

    #[test]
    fn lane_blocked_handoff_round_trip() {
        // 3 coords × 2 lanes: h/f are lane-blocked, b stays per-coord
        let msg = WorkerMsg::Handoff(Handoff {
            pid_from: 2,
            pid_to: 0,
            version: 7,
            epoch: 4,
            coords: vec![10, 11, 12],
            h_slice: vec![0.1, 0.9, 0.2, 0.8, 0.3, 0.7],
            b_slice: vec![1.0, 0.0, -1.0],
            f_slice: vec![1e-9, 0.0, 0.5, 0.25, 0.0, 0.125],
        });
        let mut buf = Vec::new();
        msg.encode(&mut buf);
        assert_eq!(buf[0], TAG_HANDOFF_ML);
        assert_eq!(WorkerMsg::decode(&buf).unwrap(), msg);
    }

    #[test]
    fn halo_round_trip() {
        let msg = WorkerMsg::HaloSlice {
            epoch: 9,
            coords: vec![0, 219],
            h: vec![0.75, 0.125],
        };
        let mut buf = Vec::new();
        msg.encode(&mut buf);
        assert_eq!(buf[0], TAG_HALO);
        assert_eq!(WorkerMsg::decode(&buf).unwrap(), msg);
    }

    #[test]
    fn lane_blocked_halo_round_trip() {
        let msg = WorkerMsg::HaloSlice {
            epoch: 9,
            coords: vec![0, 219],
            h: vec![0.75, 0.5, 0.125, 0.0625],
        };
        let mut buf = Vec::new();
        msg.encode(&mut buf);
        assert_eq!(buf[0], TAG_HALO_ML);
        assert_eq!(WorkerMsg::decode(&buf).unwrap(), msg);
    }

    #[test]
    fn multi_lane_tags_reject_a_degenerate_lane_count() {
        // lanes < 2 under an ML tag is non-canonical: plain tags are
        // the only encoding of single-lane columns
        let mut buf = vec![TAG_HALO_ML];
        write_varint(&mut buf, 9); // epoch
        write_varint(&mut buf, 2); // count
        write_varint(&mut buf, 1); // lanes — invalid
        write_deltas(&mut buf, [0u64, 219]);
        write_f64_slice(&mut buf, &[0.75, 0.125]);
        assert!(WorkerMsg::decode(&buf).is_err());
        let mut pools = ColumnPools::new(8);
        assert!(WorkerMsg::decode_pooled(&buf, &mut pools).is_err());
    }

    #[test]
    fn pooled_decode_matches_plain_decode() {
        let msgs = [
            WorkerMsg::Fluid {
                epoch: 3,
                coords: vec![1, 5, 6, 900],
                mass: vec![0.25, -0.5, 1e-17, 3.75],
                qids: vec![],
            },
            WorkerMsg::Fluid {
                epoch: 5,
                coords: vec![1, 1, 7, 900],
                mass: vec![0.25, -0.5, 1e-17, 3.75],
                qids: vec![0, 3, 3, 17],
            },
            WorkerMsg::Handoff(Handoff {
                pid_from: 2,
                pid_to: 0,
                version: 7,
                epoch: 4,
                coords: vec![10, 11, 12],
                h_slice: vec![0.1, 0.2, 0.3],
                b_slice: vec![1.0, 0.0, -1.0],
                f_slice: vec![1e-9, 0.5, 0.0],
            }),
            WorkerMsg::Handoff(Handoff {
                pid_from: 1,
                pid_to: 3,
                version: 2,
                epoch: 6,
                coords: vec![4, 9],
                h_slice: vec![0.1, 0.9, 0.2, 0.8],
                b_slice: vec![1.0, 0.0],
                f_slice: vec![0.5, 0.25, 0.0, 0.125],
            }),
            WorkerMsg::HaloSlice {
                epoch: 9,
                coords: vec![0, 219],
                h: vec![0.75, 0.125],
            },
            WorkerMsg::HaloSlice {
                epoch: 9,
                coords: vec![0, 219],
                h: vec![0.75, 0.5, 0.125, 0.0625],
            },
        ];
        let mut pools = ColumnPools::new(8);
        for msg in &msgs {
            let mut buf = Vec::new();
            msg.encode(&mut buf);
            // repeat so the second pass decodes into recycled storage
            for _ in 0..2 {
                let pooled = WorkerMsg::decode_pooled(&buf, &mut pools).unwrap();
                assert_eq!(&pooled, msg);
                pooled.reclaim(&mut pools);
            }
        }
    }

    #[test]
    fn pooled_decode_rejects_what_plain_decode_rejects() {
        let msg = WorkerMsg::Fluid {
            epoch: 1,
            coords: vec![4, 8],
            mass: vec![0.5, 0.5],
            qids: vec![2, 5],
        };
        let mut buf = Vec::new();
        msg.encode(&mut buf);
        let mut pools = ColumnPools::new(8);
        for cut in 0..buf.len() {
            assert!(
                WorkerMsg::decode_pooled(&buf[..cut], &mut pools).is_err(),
                "cut at {cut}"
            );
        }
        let mut longer = buf.clone();
        longer.push(0);
        assert!(WorkerMsg::decode_pooled(&longer, &mut pools).is_err());
        // and the pools still hand out working storage afterwards
        let ok = WorkerMsg::decode_pooled(&buf, &mut pools).unwrap();
        assert_eq!(ok, msg);
    }

    #[test]
    fn strict_decode_rejects_mutations() {
        for msg in [
            WorkerMsg::Fluid {
                epoch: 1,
                coords: vec![4, 8],
                mass: vec![0.5, 0.5],
                qids: vec![],
            },
            WorkerMsg::Fluid {
                epoch: 1,
                coords: vec![4, 8],
                mass: vec![0.5, 0.5],
                qids: vec![0, 6],
            },
        ] {
            let mut buf = Vec::new();
            msg.encode(&mut buf);
            // truncation anywhere fails
            for cut in 0..buf.len() {
                assert!(WorkerMsg::decode(&buf[..cut]).is_err(), "cut at {cut}");
            }
            // trailing garbage fails
            let mut longer = buf.clone();
            longer.push(0);
            assert!(WorkerMsg::decode(&longer).is_err());
            // unknown tag fails
            let mut bad = buf;
            bad[0] = 0x3F;
            assert!(WorkerMsg::decode(&bad).is_err());
        }
    }
}
