//! Wire encoding of [`WorkerMsg`] (DESIGN.md §8.3): what a fluid parcel,
//! an ownership handoff, and a halo slice look like as bytes.
//!
//! Layout principles, in order of importance:
//!
//! * **SoA stays SoA** — a `Fluid` parcel's mass column is one bulk
//!   little-endian `f64` copy; nothing is interleaved per entry;
//! * **coordinate columns are delta-encoded** — workers emit coalesced
//!   parcels with ascending coordinates, so the zigzag-varint delta
//!   column costs ~1 byte per coordinate instead of 4–8;
//! * **explicit epoch tags** — every payload carries the epoch (and a
//!   handoff its ownership version) so receivers can stash/foster
//!   exactly as they do in-process; the wire adds reordering and delay,
//!   never ambiguity;
//! * **strict decode** — trailing bytes, truncation, or a count that
//!   cannot fit the frame are errors that kill the connection, not
//!   best-effort data.

use crate::coordinator::worker::{Handoff, WorkerMsg};
use crate::error::Result;
use crate::transport::wire::{
    corrupt, read_deltas, read_deltas_u32_into, read_deltas_usize_into, read_f64_slice,
    read_f64_slice_into, read_varint, write_deltas, write_f64_slice, write_varint, ColumnPools,
    WireCodec,
};

/// Payload tag of [`WorkerMsg::Fluid`].
pub const TAG_FLUID: u8 = 0x10;
/// Payload tag of [`WorkerMsg::Handoff`].
pub const TAG_HANDOFF: u8 = 0x11;
/// Payload tag of [`WorkerMsg::HaloSlice`].
pub const TAG_HALO: u8 = 0x12;

fn coords_u32(raw: Vec<u64>) -> Result<Vec<u32>> {
    raw.into_iter()
        .map(|v| u32::try_from(v).map_err(|_| corrupt("coordinate exceeds u32")))
        .collect()
}

impl WireCodec for WorkerMsg {
    fn encode(&self, out: &mut Vec<u8>) {
        match self {
            WorkerMsg::Fluid {
                epoch,
                coords,
                mass,
            } => {
                debug_assert_eq!(coords.len(), mass.len());
                out.push(TAG_FLUID);
                write_varint(out, *epoch);
                write_varint(out, coords.len() as u64);
                write_deltas(out, coords.iter().map(|&c| u64::from(c)));
                write_f64_slice(out, mass);
            }
            WorkerMsg::Handoff(ho) => {
                debug_assert!(
                    ho.coords.len() == ho.h_slice.len()
                        && ho.coords.len() == ho.b_slice.len()
                        && ho.coords.len() == ho.f_slice.len()
                );
                out.push(TAG_HANDOFF);
                write_varint(out, ho.pid_from as u64);
                write_varint(out, ho.pid_to as u64);
                write_varint(out, ho.version);
                write_varint(out, ho.epoch);
                write_varint(out, ho.coords.len() as u64);
                write_deltas(out, ho.coords.iter().map(|&c| c as u64));
                write_f64_slice(out, &ho.h_slice);
                write_f64_slice(out, &ho.b_slice);
                write_f64_slice(out, &ho.f_slice);
            }
            WorkerMsg::HaloSlice { epoch, coords, h } => {
                debug_assert_eq!(coords.len(), h.len());
                out.push(TAG_HALO);
                write_varint(out, *epoch);
                write_varint(out, coords.len() as u64);
                write_deltas(out, coords.iter().map(|&c| u64::from(c)));
                write_f64_slice(out, h);
            }
        }
    }

    fn decode(buf: &[u8]) -> Result<WorkerMsg> {
        let Some(&tag) = buf.first() else {
            return Err(corrupt("empty payload"));
        };
        let mut pos = 1;
        let msg = match tag {
            TAG_FLUID => {
                let epoch = read_varint(buf, &mut pos)?;
                let count = read_varint(buf, &mut pos)? as usize;
                let coords = coords_u32(read_deltas(buf, &mut pos, count)?)?;
                let mass = read_f64_slice(buf, &mut pos, count)?;
                WorkerMsg::Fluid {
                    epoch,
                    coords,
                    mass,
                }
            }
            TAG_HANDOFF => {
                let pid_from = read_varint(buf, &mut pos)? as usize;
                let pid_to = read_varint(buf, &mut pos)? as usize;
                let version = read_varint(buf, &mut pos)?;
                let epoch = read_varint(buf, &mut pos)?;
                let count = read_varint(buf, &mut pos)? as usize;
                let coords = read_deltas(buf, &mut pos, count)?
                    .into_iter()
                    .map(|v| v as usize)
                    .collect();
                let h_slice = read_f64_slice(buf, &mut pos, count)?;
                let b_slice = read_f64_slice(buf, &mut pos, count)?;
                let f_slice = read_f64_slice(buf, &mut pos, count)?;
                WorkerMsg::Handoff(Handoff {
                    pid_from,
                    pid_to,
                    version,
                    epoch,
                    coords,
                    h_slice,
                    b_slice,
                    f_slice,
                })
            }
            TAG_HALO => {
                let epoch = read_varint(buf, &mut pos)?;
                let count = read_varint(buf, &mut pos)? as usize;
                let coords = coords_u32(read_deltas(buf, &mut pos, count)?)?;
                let h = read_f64_slice(buf, &mut pos, count)?;
                WorkerMsg::HaloSlice { epoch, coords, h }
            }
            other => return Err(corrupt(&format!("unknown payload tag {other:#04x}"))),
        };
        if pos != buf.len() {
            return Err(corrupt("trailing bytes after payload"));
        }
        Ok(msg)
    }

    /// [`WireCodec::decode`] with every column vector drawn from `pools`
    /// instead of the allocator — the wire receive path's steady state.
    /// Decodes exactly the same values as `decode` (the codec tests pin
    /// the equivalence); on any decode error the storage taken so far
    /// goes straight back to the pools.
    fn decode_pooled(buf: &[u8], pools: &mut ColumnPools) -> Result<WorkerMsg> {
        let Some(&tag) = buf.first() else {
            return Err(corrupt("empty payload"));
        };
        let mut pos = 1;
        let msg = match tag {
            TAG_FLUID => {
                let epoch = read_varint(buf, &mut pos)?;
                let count = read_varint(buf, &mut pos)? as usize;
                let mut coords = pools.u32s.take();
                let mut mass = pools.f64s.take();
                let cols = read_deltas_u32_into(buf, &mut pos, count, &mut coords)
                    .and_then(|()| read_f64_slice_into(buf, &mut pos, count, &mut mass));
                if let Err(e) = cols {
                    pools.u32s.give(coords);
                    pools.f64s.give(mass);
                    return Err(e);
                }
                WorkerMsg::Fluid {
                    epoch,
                    coords,
                    mass,
                }
            }
            TAG_HANDOFF => {
                let pid_from = read_varint(buf, &mut pos)? as usize;
                let pid_to = read_varint(buf, &mut pos)? as usize;
                let version = read_varint(buf, &mut pos)?;
                let epoch = read_varint(buf, &mut pos)?;
                let count = read_varint(buf, &mut pos)? as usize;
                let mut coords = pools.usizes.take();
                let mut h_slice = pools.f64s.take();
                let mut b_slice = pools.f64s.take();
                let mut f_slice = pools.f64s.take();
                let cols = read_deltas_usize_into(buf, &mut pos, count, &mut coords)
                    .and_then(|()| read_f64_slice_into(buf, &mut pos, count, &mut h_slice))
                    .and_then(|()| read_f64_slice_into(buf, &mut pos, count, &mut b_slice))
                    .and_then(|()| read_f64_slice_into(buf, &mut pos, count, &mut f_slice));
                if let Err(e) = cols {
                    pools.usizes.give(coords);
                    pools.f64s.give(h_slice);
                    pools.f64s.give(b_slice);
                    pools.f64s.give(f_slice);
                    return Err(e);
                }
                WorkerMsg::Handoff(Handoff {
                    pid_from,
                    pid_to,
                    version,
                    epoch,
                    coords,
                    h_slice,
                    b_slice,
                    f_slice,
                })
            }
            TAG_HALO => {
                let epoch = read_varint(buf, &mut pos)?;
                let count = read_varint(buf, &mut pos)? as usize;
                let mut coords = pools.u32s.take();
                let mut h = pools.f64s.take();
                let cols = read_deltas_u32_into(buf, &mut pos, count, &mut coords)
                    .and_then(|()| read_f64_slice_into(buf, &mut pos, count, &mut h));
                if let Err(e) = cols {
                    pools.u32s.give(coords);
                    pools.f64s.give(h);
                    return Err(e);
                }
                WorkerMsg::HaloSlice { epoch, coords, h }
            }
            other => return Err(corrupt(&format!("unknown payload tag {other:#04x}"))),
        };
        if pos != buf.len() {
            msg.reclaim(pools);
            return Err(corrupt("trailing bytes after payload"));
        }
        Ok(msg)
    }

    /// Return every column vector to `pools` — called by the wire send
    /// path after the payload has been encoded into its frame, closing
    /// the storage cycle (decode → worker → coalesce → encode → pools).
    fn reclaim(self, pools: &mut ColumnPools) {
        match self {
            WorkerMsg::Fluid { coords, mass, .. } => {
                pools.u32s.give(coords);
                pools.f64s.give(mass);
            }
            WorkerMsg::Handoff(ho) => {
                pools.usizes.give(ho.coords);
                pools.f64s.give(ho.h_slice);
                pools.f64s.give(ho.b_slice);
                pools.f64s.give(ho.f_slice);
            }
            WorkerMsg::HaloSlice { coords, h, .. } => {
                pools.u32s.give(coords);
                pools.f64s.give(h);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn round_trip(msg: &WorkerMsg) -> WorkerMsg {
        let mut buf = Vec::new();
        msg.encode(&mut buf);
        WorkerMsg::decode(&buf).expect("decode what we encoded")
    }

    #[test]
    fn fluid_round_trip() {
        let msg = WorkerMsg::Fluid {
            epoch: 3,
            coords: vec![1, 5, 6, 900],
            mass: vec![0.25, -0.5, 1e-17, 3.75],
        };
        assert_eq!(round_trip(&msg), msg);
    }

    #[test]
    fn empty_fluid_round_trip() {
        let msg = WorkerMsg::Fluid {
            epoch: 0,
            coords: vec![],
            mass: vec![],
        };
        assert_eq!(round_trip(&msg), msg);
    }

    #[test]
    fn handoff_round_trip() {
        let msg = WorkerMsg::Handoff(Handoff {
            pid_from: 2,
            pid_to: 0,
            version: 7,
            epoch: 4,
            coords: vec![10, 11, 12],
            h_slice: vec![0.1, 0.2, 0.3],
            b_slice: vec![1.0, 0.0, -1.0],
            f_slice: vec![1e-9, 0.5, 0.0],
        });
        assert_eq!(round_trip(&msg), msg);
    }

    #[test]
    fn halo_round_trip() {
        let msg = WorkerMsg::HaloSlice {
            epoch: 9,
            coords: vec![0, 219],
            h: vec![0.75, 0.125],
        };
        assert_eq!(round_trip(&msg), msg);
    }

    #[test]
    fn pooled_decode_matches_plain_decode() {
        let msgs = [
            WorkerMsg::Fluid {
                epoch: 3,
                coords: vec![1, 5, 6, 900],
                mass: vec![0.25, -0.5, 1e-17, 3.75],
            },
            WorkerMsg::Handoff(Handoff {
                pid_from: 2,
                pid_to: 0,
                version: 7,
                epoch: 4,
                coords: vec![10, 11, 12],
                h_slice: vec![0.1, 0.2, 0.3],
                b_slice: vec![1.0, 0.0, -1.0],
                f_slice: vec![1e-9, 0.5, 0.0],
            }),
            WorkerMsg::HaloSlice {
                epoch: 9,
                coords: vec![0, 219],
                h: vec![0.75, 0.125],
            },
        ];
        let mut pools = ColumnPools::new(8);
        for msg in &msgs {
            let mut buf = Vec::new();
            msg.encode(&mut buf);
            // repeat so the second pass decodes into recycled storage
            for _ in 0..2 {
                let pooled = WorkerMsg::decode_pooled(&buf, &mut pools).unwrap();
                assert_eq!(&pooled, msg);
                pooled.reclaim(&mut pools);
            }
        }
    }

    #[test]
    fn pooled_decode_rejects_what_plain_decode_rejects() {
        let msg = WorkerMsg::Fluid {
            epoch: 1,
            coords: vec![4, 8],
            mass: vec![0.5, 0.5],
        };
        let mut buf = Vec::new();
        msg.encode(&mut buf);
        let mut pools = ColumnPools::new(8);
        for cut in 0..buf.len() {
            assert!(
                WorkerMsg::decode_pooled(&buf[..cut], &mut pools).is_err(),
                "cut at {cut}"
            );
        }
        let mut longer = buf.clone();
        longer.push(0);
        assert!(WorkerMsg::decode_pooled(&longer, &mut pools).is_err());
        // and the pools still hand out working storage afterwards
        let ok = WorkerMsg::decode_pooled(&buf, &mut pools).unwrap();
        assert_eq!(ok, msg);
    }

    #[test]
    fn strict_decode_rejects_mutations() {
        let msg = WorkerMsg::Fluid {
            epoch: 1,
            coords: vec![4, 8],
            mass: vec![0.5, 0.5],
        };
        let mut buf = Vec::new();
        msg.encode(&mut buf);
        // truncation anywhere fails
        for cut in 0..buf.len() {
            assert!(WorkerMsg::decode(&buf[..cut]).is_err(), "cut at {cut}");
        }
        // trailing garbage fails
        let mut longer = buf.clone();
        longer.push(0);
        assert!(WorkerMsg::decode(&longer).is_err());
        // unknown tag fails
        let mut bad = buf;
        bad[0] = 0x3F;
        assert!(WorkerMsg::decode(&bad).is_err());
    }
}
