//! Bench: crash-recovery cost vs a cold restart, and the steady-state
//! overhead of incremental checkpointing.
//!
//! Scenario: K = 3 streaming workers on a power-law graph. Three runs:
//!
//!   * cold          — plain converge, no crash tolerance (the baseline
//!                     and the stand-in for "restart from scratch").
//!   * checkpointed  — same solve with incremental per-worker H
//!                     checkpoints flowing (the dirty-slot journal);
//!                     the wall-clock ratio against `cold` is the
//!                     checkpointing tax, which must stay near 1.
//!   * recovery      — the checkpointed engine converges, a worker is
//!                     crashed (no drain, no goodbye), and the wall
//!                     clock measures detect → restore checkpoint H →
//!                     recompute fluid (`F = b − (I−P)·H`) → re-settle.
//!
//! A restart-from-scratch pays `cold` again; recovery only re-diffuses
//! the residual the checkpoint had not yet absorbed, so
//! `recovery_vs_cold_speedup` must stay above 1.0. Emits
//! `BENCH_recovery.json` for the CI perf gate (`tools/bench_gate.py
//! --kind recovery`).

use diter::bench_harness::{bench_header, bench_json_dir, fmt_secs, Json, Table};
use diter::coordinator::{DistributedConfig, StreamingEngine};
use diter::graph::{power_law_web_graph, MutableDigraph};
use diter::partition::Partition;
use diter::solver::SequenceKind;
use std::time::Duration;

fn base_cfg(n: usize, k: usize, tol: f64, seed: u64) -> DistributedConfig {
    let mut cfg = DistributedConfig::new(Partition::contiguous(n, k).unwrap())
        .with_tol(tol)
        .with_seed(seed)
        .with_sequence(SequenceKind::GreedyMaxFluid);
    cfg.max_wall = Duration::from_secs(600);
    cfg
}

fn main() {
    bench_header(
        "recovery",
        "crash recovery from incremental checkpoints vs cold restart (K=3)",
    );
    let n = std::env::var("DITER_BENCH_N")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(3_000usize);
    let k = 3usize;
    let tol = 1e-9;
    let seed = 17u64;
    let checkpoint_every = Duration::from_millis(2);
    println!("graph: {n} nodes, K={k}, checkpoint every {checkpoint_every:?}, tol {tol:.0e}\n");

    let g = power_law_web_graph(n, 6, 0.1, seed);

    // cold: the restart-from-scratch baseline
    let mg = MutableDigraph::from_digraph(&g, n);
    let mut eng = StreamingEngine::new(mg, 0.85, true, base_cfg(n, k, tol, seed)).unwrap();
    let init = eng.converge().unwrap();
    assert!(init.solution.converged, "cold solve must converge");
    let cold_wall = init.solution.wall_secs;
    eng.finish().unwrap();

    // checkpointed: the same solve with the journal flowing
    let mg = MutableDigraph::from_digraph(&g, n);
    let cfg = base_cfg(n, k, tol, seed)
        .with_checkpoint_every(checkpoint_every)
        .with_heartbeat(Duration::from_millis(500));
    let mut eng = StreamingEngine::new(mg, 0.85, true, cfg).unwrap();
    let init = eng.converge().unwrap();
    assert!(init.solution.converged, "checkpointed solve must converge");
    let ckpt_wall = init.solution.wall_secs;

    // recovery: crash a worker at the fixed point, then measure
    // detect → restore → recompute → re-settle on the same engine
    eng.pool_mut().kill(1);
    let report = eng.converge().unwrap();
    assert!(report.solution.converged, "recovered solve must converge");
    let recovery_wall = report.solution.wall_secs;
    let stats = eng.pool_stats();
    eng.finish().unwrap();
    assert_eq!(stats.crashes, 1, "the crash must be detected");
    assert_eq!(stats.recoveries, 1, "the crash must be recovered");

    let overhead = ckpt_wall / cold_wall.max(1e-9);
    let speedup = cold_wall / recovery_wall.max(1e-9);
    let mut table = Table::new(&["run", "wall", "vs-cold"]);
    table.row(&["cold solve".into(), fmt_secs(cold_wall), "1.00x".into()]);
    table.row(&[
        "checkpointed solve".into(),
        fmt_secs(ckpt_wall),
        format!("{overhead:.2}x (tax)"),
    ]);
    table.row(&[
        "crash recovery".into(),
        fmt_secs(recovery_wall),
        format!("{speedup:.2}x faster"),
    ]);
    print!("{}", table.render());
    println!(
        "\npool: crashes {} recoveries {}",
        stats.crashes, stats.recoveries
    );

    let bench_env = std::env::var("DITER_BENCH_ENV").unwrap_or_else(|_| "local".into());
    let json = Json::new()
        .int_field("schema", 1)
        .str_field("bench", "recovery")
        .bool_field("measured", true)
        .str_field("environment", &bench_env)
        .int_field("n", n as u64)
        .int_field("k", k as u64)
        .num_field("tol", tol)
        .num_field("checkpoint_every_secs", checkpoint_every.as_secs_f64())
        .num_field("cold_time_to_converge_secs", cold_wall)
        .num_field("checkpointed_time_to_converge_secs", ckpt_wall)
        .num_field("recovery_time_to_converge_secs", recovery_wall)
        .num_field("checkpoint_overhead_ratio", overhead)
        .num_field("recovery_vs_cold_speedup", speedup)
        .int_field("pool_crashes", stats.crashes)
        .int_field("pool_recoveries", stats.recoveries);
    let path = bench_json_dir().join("BENCH_recovery.json");
    json.write(&path).expect("write BENCH_recovery.json");
    println!("\nwrote {}", path.display());

    assert!(
        speedup > 1.0,
        "recovery must beat a cold restart (got {speedup:.2}x) — the \
         checkpoint restore is pure overhead otherwise"
    );
    println!("recovery beats cold restart: {speedup:.2}x (checkpoint tax: {overhead:.2}x)");
}
