//! Bench: regenerate paper Figure 1 — A(1), 2 PIDs, no inter-block
//! coupling. Expected shape: D-iteration ≤ Gauss–Seidel < Jacobi, and the
//! 2-PID distributed run shows a per-processor gain factor of ≈2.

use diter::bench_harness::bench_header;
use diter::figures::{figure_gain, render_figure};

fn main() {
    bench_header(
        "fig1",
        "Figure 1: 2 PIDs on A(1) (uncoupled blocks) — error vs iteration",
    );
    print!("{}", render_figure(1, 20).expect("figure 1"));
    let gain = figure_gain(1, 1e-8, 200)
        .expect("gain")
        .expect("tolerance reached");
    println!("\nper-processor gain of 2 PIDs at 1e-8: {gain:.2}x (paper: ~2x)");
}
