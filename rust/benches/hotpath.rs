//! Perf bench: the hot paths of each layer, for the EXPERIMENTS.md §Perf
//! iteration log.
//!
//!  * L3 sweep kernel: sparse row sweeps (the inner loop of every PID)
//!  * L3 fluid diffusion: the V2 per-node diffusion
//!  * transport: send/recv round-trips and coalescing overhead
//!  * end-to-end: V2 PageRank updates/second at K = cores
//!  * kernel head-to-head: global-walk vs local-block vs blocked, same
//!    graph and binary, with per-solve allocation counts from the
//!    installed [`CountingAlloc`]
//!  * runtime (if artifacts present): PJRT d_round dispatch latency vs the
//!    equivalent rust sweep, amortization vs block size
//!
//! Emits `BENCH_hotpath.json` (diffusions/sec and edges/sec per kernel,
//! the blocked/local and local/global speedups, allocation counts) into
//! `DITER_BENCH_JSON_DIR` (default `.`). The committed copy at the repo
//! root is the baseline `tools/bench_gate.py --kind hotpath` compares
//! against. Env knobs: `DITER_BENCH_N` (head-to-head graph size),
//! `DITER_BENCH_ENV` (recorded measurement environment).

use std::time::Duration;

use diter::bench_harness::{bench, bench_header, bench_json_dir, black_box, fmt_secs, Json, Table};
use diter::coordinator::{v2, DistributedConfig, KernelKind};
use diter::graph::{pagerank_system, power_law_web_graph};
use diter::partition::Partition;
use diter::perf::CountingAlloc;
use diter::prng::Xoshiro256pp;
use diter::runtime::Runtime;
use diter::solver::{DIteration, FixedPointProblem, SequenceKind, SolveOptions, Solver};
use diter::transport::{bus, BusConfig, CoalesceBuffer, CoalescePolicy};

// Count every heap allocation the bench makes — the kernel head-to-head
// reports allocs/solve, turning "the blocked kernel is allocation-free in
// steady state" into a measured number instead of a claim.
#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc::new();

/// One kernel's end-to-end V2 solve: throughput plus allocator traffic.
struct KernelRun {
    updates: u64,
    wall_secs: f64,
    allocations: u64,
}

impl KernelRun {
    fn diffusions_per_sec(&self) -> f64 {
        self.updates as f64 / self.wall_secs.max(1e-9)
    }

    /// Edge traversals/sec: each diffusion walks the node's out-column, so
    /// edges ≈ updates × mean out-degree (exact only in aggregate).
    fn edges_per_sec(&self, avg_deg: f64) -> f64 {
        self.diffusions_per_sec() * avg_deg
    }

    fn allocs_per_kupdate(&self) -> f64 {
        self.allocations as f64 * 1e3 / self.updates.max(1) as f64
    }

    fn to_json(&self, avg_deg: f64) -> Json {
        Json::new()
            .num_field("diffusions_per_sec", self.diffusions_per_sec())
            .num_field("edges_per_sec", self.edges_per_sec(avg_deg))
            .int_field("updates", self.updates)
            .num_field("wall_secs", self.wall_secs)
            .int_field("allocations", self.allocations)
            .num_field("allocs_per_kupdate", self.allocs_per_kupdate())
    }
}

/// Solve the same problem twice with one kernel (cold + warm) and report
/// the warm run — the steady-state number the gate tracks. Allocations are
/// process-wide across the warm solve (the workers are threads).
fn run_kernel(
    problem: &FixedPointProblem,
    base: &DistributedConfig,
    kernel: KernelKind,
) -> KernelRun {
    let cfg = base.clone().with_kernel(kernel);
    let cold = v2::solve_v2(problem, &cfg).expect("cold solve");
    assert!(cold.converged, "[{}] cold solve must converge", kernel.name());
    let a0 = CountingAlloc::total_allocations();
    let sol = v2::solve_v2(problem, &cfg).expect("warm solve");
    let allocations = CountingAlloc::total_allocations() - a0;
    assert!(sol.converged, "[{}] warm solve must converge", kernel.name());
    KernelRun {
        updates: sol.total_updates,
        wall_secs: sol.wall_secs,
        allocations,
    }
}

fn main() {
    bench_header("hotpath", "per-layer hot-path microbenchmarks");
    let bench_env = std::env::var("DITER_BENCH_ENV").unwrap_or_else(|_| "local".into());
    let mut table = Table::new(&["bench", "mean", "p50", "p99", "throughput"]);

    // --- L3 sparse sweep (the eq. 6 inner loop) -------------------------
    let n = 50_000;
    let g = power_law_web_graph(n, 8, 0.1, 3);
    let sys = pagerank_system(&g, 0.85, false).unwrap();
    let problem = FixedPointProblem::new(sys.matrix.clone(), sys.b.clone()).unwrap();
    let csr = problem.matrix().csr();
    let mut h = problem.b().to_vec();
    let s = bench(3, 10, || {
        for i in 0..n {
            h[i] = csr.row_dot(i, &h) + problem.b()[i];
        }
        h[0]
    });
    table.row(&[
        "sweep 50k rows (~8 nnz)".into(),
        fmt_secs(s.mean),
        fmt_secs(s.p50),
        fmt_secs(s.p99),
        format!("{:.2e} upd/s", n as f64 / s.mean),
    ]);

    // --- L3 fluid diffusion (V2 inner loop) -----------------------------
    let mut f = problem.b().to_vec();
    let mut hh = vec![0.0; n];
    let s = bench(3, 10, || {
        for i in 0..n {
            DIteration::diffuse_once(&problem, &mut hh, &mut f, i);
        }
        f[0]
    });
    table.row(&[
        "diffuse 50k nodes".into(),
        fmt_secs(s.mean),
        fmt_secs(s.p50),
        fmt_secs(s.p99),
        format!("{:.2e} upd/s", n as f64 / s.mean),
    ]);

    // --- transport round-trip -------------------------------------------
    let (mut eps, _m) = bus::<Vec<(usize, f64)>>(2, &BusConfig::default());
    let mut b_ep = eps.pop().unwrap();
    let mut a_ep = eps.pop().unwrap();
    let parcel: Vec<(usize, f64)> = (0..64).map(|i| (i, 0.5)).collect();
    let s = bench(100, 2_000, || {
        a_ep.send(1, parcel.clone(), 1.0, 1040).unwrap();
        while b_ep.try_recv().is_none() {}
        a_ep.collect_acks();
    });
    table.row(&[
        "bus send+recv (64-entry)".into(),
        fmt_secs(s.mean),
        fmt_secs(s.p50),
        fmt_secs(s.p99),
        format!("{:.2e} msg/s", 1.0 / s.mean),
    ]);

    // --- coalescing -------------------------------------------------------
    let mut buf = CoalesceBuffer::new(4, CoalescePolicy::default());
    let mut rng = Xoshiro256pp::seed_from_u64(1);
    let targets: Vec<(usize, usize)> =
        (0..10_000).map(|_| (rng.below(4), rng.below(5_000))).collect();
    let s = bench(3, 50, || {
        for &(d, j) in &targets {
            buf.add(d, j, 1e-6);
        }
        let mut out = 0usize;
        buf.flush(true, |_, coords, _, _, _| out += coords.len());
        black_box(out)
    });
    table.row(&[
        "coalesce 10k keyed adds".into(),
        fmt_secs(s.mean),
        fmt_secs(s.p50),
        fmt_secs(s.p99),
        format!("{:.2e} add/s", 1e4 / s.mean),
    ]);
    // the remnant kernel's route: slots interned once, then indexed adds
    let slots: Vec<(usize, u32)> = targets
        .iter()
        .map(|&(d, j)| (d, buf.intern(d, j)))
        .collect();
    let s = bench(3, 50, || {
        for &(d, sl) in &slots {
            buf.add_slot(d, sl, 1e-6);
        }
        let mut out = 0usize;
        buf.flush(true, |_, coords, _, _, _| out += coords.len());
        black_box(out)
    });
    table.row(&[
        "coalesce 10k slot adds".into(),
        fmt_secs(s.mean),
        fmt_secs(s.p50),
        fmt_secs(s.p99),
        format!("{:.2e} add/s", 1e4 / s.mean),
    ]);

    // --- end-to-end V2 ----------------------------------------------------
    let n2 = std::env::var("DITER_BENCH_N")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(20_000usize);
    let g2 = power_law_web_graph(n2, 8, 0.1, 5);
    let sys2 = pagerank_system(&g2, 0.85, false).unwrap();
    let problem2 = FixedPointProblem::new(sys2.matrix.clone(), sys2.b.clone()).unwrap();
    let k = std::thread::available_parallelism().map(|c| c.get().min(8)).unwrap_or(4);
    let mut cfg = DistributedConfig::new(Partition::contiguous(n2, k).unwrap())
        .with_tol(1e-9)
        .with_sequence(SequenceKind::GreedyMaxFluid);
    cfg.max_wall = Duration::from_secs(60);
    let sol = v2::solve_v2(&problem2, &cfg).unwrap();
    table.row(&[
        format!("e2e V2 pagerank 20k, K={k}"),
        fmt_secs(sol.wall_secs),
        "-".into(),
        "-".into(),
        format!("{:.2e} upd/s", sol.updates_per_sec()),
    ]);
    // sequential for comparison
    let sw = diter::metrics::Stopwatch::start();
    let seq = DIteration::greedy()
        .solve(
            &problem2,
            &SolveOptions {
                tol: 1e-9,
                max_cost: 100_000.0,
                trace_every: 0.0,
                exact: None,
            },
        )
        .unwrap();
    let wall = sw.elapsed_secs();
    table.row(&[
        "e2e sequential greedy 20k".into(),
        fmt_secs(wall),
        "-".into(),
        "-".into(),
        format!("{:.2e} upd/s", seq.cost * n2 as f64 / wall),
    ]);

    // --- kernel head-to-head: global vs local vs blocked ------------------
    let avg_deg = g2.m() as f64 / n2 as f64;
    let global = run_kernel(&problem2, &cfg, KernelKind::GlobalWalk);
    let local = run_kernel(&problem2, &cfg, KernelKind::LocalBlock);
    let blocked = run_kernel(&problem2, &cfg, KernelKind::Blocked);
    let local_vs_global = local.diffusions_per_sec() / global.diffusions_per_sec().max(1e-9);
    let blocked_vs_local = blocked.diffusions_per_sec() / local.diffusions_per_sec().max(1e-9);
    let mut head = Table::new(&["kernel", "diff/s", "edges/s", "allocs", "allocs/kupd"]);
    for (name, r) in [
        ("global-walk", &global),
        ("local-block", &local),
        ("blocked", &blocked),
    ] {
        head.row(&[
            name.into(),
            format!("{:.2e}", r.diffusions_per_sec()),
            format!("{:.2e}", r.edges_per_sec(avg_deg)),
            r.allocations.to_string(),
            format!("{:.2}", r.allocs_per_kupdate()),
        ]);
    }
    print!("{}", head.render());
    println!(
        "\nlocal vs global: {local_vs_global:.2}x; blocked vs local: {blocked_vs_local:.2}x \
         diffusions/sec (warm solve, {n2} nodes, K={k})"
    );

    let json = Json::new()
        .int_field("schema", 1)
        .str_field("bench", "hotpath")
        .bool_field("measured", true)
        .str_field("environment", &bench_env)
        .int_field("n", n2 as u64)
        .int_field("k", k as u64)
        .num_field("avg_out_degree", avg_deg)
        .obj_field("global", global.to_json(avg_deg))
        .obj_field("local", local.to_json(avg_deg))
        .obj_field("blocked", blocked.to_json(avg_deg))
        .num_field("local_vs_global_speedup", local_vs_global)
        .num_field("blocked_vs_local_speedup", blocked_vs_local);
    let path = bench_json_dir().join("BENCH_hotpath.json");
    json.write(&path).expect("write BENCH_hotpath.json");
    println!("wrote {}", path.display());

    // --- PJRT runtime dispatch (optional) ---------------------------------
    if Runtime::artifacts_available() {
        let mut rt = Runtime::load_default().unwrap();
        for &(m, nn) in &[(2usize, 4usize), (32, 128), (64, 256), (128, 512)] {
            if rt.manifest().find("d_sweep", &[m, nn]).is_none() {
                continue;
            }
            let mut rng = Xoshiro256pp::seed_from_u64(9);
            let p_rows: Vec<f64> = (0..m * nn).map(|_| rng.uniform(-0.01, 0.01)).collect();
            let idx: Vec<i32> = (0..m as i32).collect();
            let hv: Vec<f64> = (0..nn).map(|_| rng.uniform(-1.0, 1.0)).collect();
            let bv: Vec<f64> = (0..m).map(|_| rng.uniform(-1.0, 1.0)).collect();
            // warmup includes compile
            let s = bench(3, 50, || {
                rt.d_sweep(m, nn, &p_rows, &idx, &hv, &bv).unwrap()
            });
            table.row(&[
                format!("PJRT d_sweep {m}x{nn}"),
                fmt_secs(s.mean),
                fmt_secs(s.p50),
                fmt_secs(s.p99),
                format!("{:.2e} upd/s", m as f64 / s.mean),
            ]);
        }
    } else {
        println!("(PJRT rows skipped: run `make artifacts` first)");
    }

    print!("{}", table.render());
}
