//! Perf bench: the hot paths of each layer, for the EXPERIMENTS.md §Perf
//! iteration log.
//!
//!  * L3 sweep kernel: sparse row sweeps (the inner loop of every PID)
//!  * L3 fluid diffusion: the V2 per-node diffusion
//!  * transport: send/recv round-trips and coalescing overhead
//!  * end-to-end: V2 PageRank updates/second at K = cores
//!  * runtime (if artifacts present): PJRT d_round dispatch latency vs the
//!    equivalent rust sweep, amortization vs block size

use std::time::Duration;

use diter::bench_harness::{bench, bench_header, black_box, fmt_secs, Table};
use diter::coordinator::{v2, DistributedConfig};
use diter::graph::{pagerank_system, power_law_web_graph};
use diter::partition::Partition;
use diter::prng::Xoshiro256pp;
use diter::runtime::Runtime;
use diter::solver::{DIteration, FixedPointProblem, SequenceKind, SolveOptions, Solver};
use diter::transport::{bus, BusConfig, CoalesceBuffer, CoalescePolicy};

fn main() {
    bench_header("hotpath", "per-layer hot-path microbenchmarks");
    let mut table = Table::new(&["bench", "mean", "p50", "p99", "throughput"]);

    // --- L3 sparse sweep (the eq. 6 inner loop) -------------------------
    let n = 50_000;
    let g = power_law_web_graph(n, 8, 0.1, 3);
    let sys = pagerank_system(&g, 0.85, false).unwrap();
    let problem = FixedPointProblem::new(sys.matrix.clone(), sys.b.clone()).unwrap();
    let csr = problem.matrix().csr();
    let mut h = problem.b().to_vec();
    let s = bench(3, 10, || {
        for i in 0..n {
            h[i] = csr.row_dot(i, &h) + problem.b()[i];
        }
        h[0]
    });
    table.row(&[
        "sweep 50k rows (~8 nnz)".into(),
        fmt_secs(s.mean),
        fmt_secs(s.p50),
        fmt_secs(s.p99),
        format!("{:.2e} upd/s", n as f64 / s.mean),
    ]);

    // --- L3 fluid diffusion (V2 inner loop) -----------------------------
    let mut f = problem.b().to_vec();
    let mut hh = vec![0.0; n];
    let s = bench(3, 10, || {
        for i in 0..n {
            DIteration::diffuse_once(&problem, &mut hh, &mut f, i);
        }
        f[0]
    });
    table.row(&[
        "diffuse 50k nodes".into(),
        fmt_secs(s.mean),
        fmt_secs(s.p50),
        fmt_secs(s.p99),
        format!("{:.2e} upd/s", n as f64 / s.mean),
    ]);

    // --- transport round-trip -------------------------------------------
    let (mut eps, _m) = bus::<Vec<(usize, f64)>>(2, &BusConfig::default());
    let mut b_ep = eps.pop().unwrap();
    let mut a_ep = eps.pop().unwrap();
    let parcel: Vec<(usize, f64)> = (0..64).map(|i| (i, 0.5)).collect();
    let s = bench(100, 2_000, || {
        a_ep.send(1, parcel.clone(), 1.0, 1040).unwrap();
        while b_ep.try_recv().is_none() {}
        a_ep.collect_acks();
    });
    table.row(&[
        "bus send+recv (64-entry)".into(),
        fmt_secs(s.mean),
        fmt_secs(s.p50),
        fmt_secs(s.p99),
        format!("{:.2e} msg/s", 1.0 / s.mean),
    ]);

    // --- coalescing -------------------------------------------------------
    let mut buf = CoalesceBuffer::new(4, CoalescePolicy::default());
    let mut rng = Xoshiro256pp::seed_from_u64(1);
    let targets: Vec<(usize, usize)> =
        (0..10_000).map(|_| (rng.below(4), rng.below(5_000))).collect();
    let s = bench(3, 50, || {
        for &(d, j) in &targets {
            buf.add(d, j, 1e-6);
        }
        let mut out = 0usize;
        buf.flush(true, |_, coords, _, _| out += coords.len());
        black_box(out)
    });
    table.row(&[
        "coalesce 10k keyed adds".into(),
        fmt_secs(s.mean),
        fmt_secs(s.p50),
        fmt_secs(s.p99),
        format!("{:.2e} add/s", 1e4 / s.mean),
    ]);
    // the remnant kernel's route: slots interned once, then indexed adds
    let slots: Vec<(usize, u32)> = targets
        .iter()
        .map(|&(d, j)| (d, buf.intern(d, j)))
        .collect();
    let s = bench(3, 50, || {
        for &(d, sl) in &slots {
            buf.add_slot(d, sl, 1e-6);
        }
        let mut out = 0usize;
        buf.flush(true, |_, coords, _, _| out += coords.len());
        black_box(out)
    });
    table.row(&[
        "coalesce 10k slot adds".into(),
        fmt_secs(s.mean),
        fmt_secs(s.p50),
        fmt_secs(s.p99),
        format!("{:.2e} add/s", 1e4 / s.mean),
    ]);

    // --- end-to-end V2 ----------------------------------------------------
    let n2 = 20_000;
    let g2 = power_law_web_graph(n2, 8, 0.1, 5);
    let sys2 = pagerank_system(&g2, 0.85, false).unwrap();
    let problem2 = FixedPointProblem::new(sys2.matrix.clone(), sys2.b.clone()).unwrap();
    let k = std::thread::available_parallelism().map(|c| c.get().min(8)).unwrap_or(4);
    let mut cfg = DistributedConfig::new(Partition::contiguous(n2, k).unwrap())
        .with_tol(1e-9)
        .with_sequence(SequenceKind::GreedyMaxFluid);
    cfg.max_wall = Duration::from_secs(60);
    let sol = v2::solve_v2(&problem2, &cfg).unwrap();
    table.row(&[
        format!("e2e V2 pagerank 20k, K={k}"),
        fmt_secs(sol.wall_secs),
        "-".into(),
        "-".into(),
        format!("{:.2e} upd/s", sol.updates_per_sec()),
    ]);
    // sequential for comparison
    let sw = diter::metrics::Stopwatch::start();
    let seq = DIteration::greedy()
        .solve(
            &problem2,
            &SolveOptions {
                tol: 1e-9,
                max_cost: 100_000.0,
                trace_every: 0.0,
                exact: None,
            },
        )
        .unwrap();
    let wall = sw.elapsed_secs();
    table.row(&[
        "e2e sequential greedy 20k".into(),
        fmt_secs(wall),
        "-".into(),
        "-".into(),
        format!("{:.2e} upd/s", seq.cost * n2 as f64 / wall),
    ]);

    // --- PJRT runtime dispatch (optional) ---------------------------------
    if Runtime::artifacts_available() {
        let mut rt = Runtime::load_default().unwrap();
        for &(m, nn) in &[(2usize, 4usize), (32, 128), (64, 256), (128, 512)] {
            if rt.manifest().find("d_sweep", &[m, nn]).is_none() {
                continue;
            }
            let mut rng = Xoshiro256pp::seed_from_u64(9);
            let p_rows: Vec<f64> = (0..m * nn).map(|_| rng.uniform(-0.01, 0.01)).collect();
            let idx: Vec<i32> = (0..m as i32).collect();
            let hv: Vec<f64> = (0..nn).map(|_| rng.uniform(-1.0, 1.0)).collect();
            let bv: Vec<f64> = (0..m).map(|_| rng.uniform(-1.0, 1.0)).collect();
            // warmup includes compile
            let s = bench(3, 50, || {
                rt.d_sweep(m, nn, &p_rows, &idx, &hv, &bv).unwrap()
            });
            table.row(&[
                format!("PJRT d_sweep {m}x{nn}"),
                fmt_secs(s.mean),
                fmt_secs(s.p50),
                fmt_secs(s.p99),
                format!("{:.2e} upd/s", m as f64 / s.mean),
            ]);
        }
    } else {
        println!("(PJRT rows skipped: run `make artifacts` first)");
    }

    print!("{}", table.render());
}
