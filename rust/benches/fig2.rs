//! Bench: regenerate paper Figure 2 — A(2), 2 PIDs, moderate coupling
//! between Ω₁ and Ω₂. Expected shape: "still a visible gain factor",
//! smaller than Figure 1's ≈2.

use diter::bench_harness::bench_header;
use diter::figures::{figure_gain, render_figure};

fn main() {
    bench_header(
        "fig2",
        "Figure 2: 2 PIDs on A(2) (coupled blocks) — error vs iteration",
    );
    print!("{}", render_figure(2, 20).expect("figure 2"));
    let gain = figure_gain(2, 1e-8, 300)
        .expect("gain")
        .expect("tolerance reached");
    println!("\nper-processor gain of 2 PIDs at 1e-8: {gain:.2}x (paper: visible, < fig1)");
}
