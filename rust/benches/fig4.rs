//! Bench: regenerate paper Figure 4 — live evolution of P: A is solved up
//! to iteration 5, then the matrix switches to A' (entry (2,4) = 1) and
//! the computation continues via the §3.2 rebase, 2 PIDs. Expected shape:
//! error (to the NEW limit) plateaus until the switch, then converges.

use diter::bench_harness::bench_header;
use diter::figures::render_figure;

fn main() {
    bench_header(
        "fig4",
        "Figure 4: 2 PIDs, P -> P' at iteration 6 (§3.2 warm rebase)",
    );
    print!("{}", render_figure(4, 24).expect("figure 4"));
    println!("\n(the error is measured against the NEW system's limit X';");
    println!(" the plateau before iteration 6 is the distance between the two limits)");
}
