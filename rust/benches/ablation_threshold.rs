//! Ablation (§4.1): the sharing-threshold policy — initial T₀ and the
//! divisor α. Measures parallel cost, message volume and wall time on a
//! coupled block system. Expected shape: very small T₀ over-shares
//! (message blow-up), very large T₀ under-shares (slow convergence); α
//! trades the two off — the paper's geometric T_k/α keeps both bounded.

use std::time::Duration;

use diter::bench_harness::{bench_header, fmt_secs, Table};
use diter::coordinator::{v2, DistributedConfig};
use diter::graph::block_coupled_matrix;
use diter::partition::Partition;
use diter::solver::FixedPointProblem;
use diter::sparse::SparseMatrix;

fn main() {
    bench_header(
        "ablation_threshold",
        "threshold policy sweep: T0 x alpha on a coupled 512-node system, K=4",
    );
    let n = 512;
    let k = 4;
    let p = block_coupled_matrix(n, k, 0.45, 0.2, 6, 3);
    let problem = FixedPointProblem::new(SparseMatrix::from_csr(p), vec![1.0; n]).unwrap();
    let mut table = Table::new(&["T0", "alpha", "wall", "parallel-cost", "msgs", "converged"]);
    for t0 in [1e-1, 1e-3, 1e-6] {
        for alpha in [1.5, 2.0, 4.0, 8.0] {
            let mut cfg = DistributedConfig::new(Partition::contiguous(n, k).unwrap())
                .with_tol(1e-10)
                .with_seed(11);
            cfg.threshold0 = t0;
            cfg.threshold_alpha = alpha;
            cfg.max_wall = Duration::from_secs(30);
            let sol = v2::solve_v2(&problem, &cfg).unwrap();
            table.row(&[
                format!("{t0:.0e}"),
                format!("{alpha}"),
                fmt_secs(sol.wall_secs),
                format!("{:.1}", sol.cost),
                sol.metrics["msgs_sent"].to_string(),
                sol.converged.to_string(),
            ]);
        }
    }
    print!("{}", table.render());
}
