//! Bench: multi-tenant query serving throughput — L concurrent query
//! lanes sharing one matrix walk vs a sequential one-query-at-a-time
//! baseline, with graph churn landing mid-serve.
//!
//! D-iteration is linear in B, so lanes amortize the matrix walk and the
//! wire: the batched configuration should complete the same query load
//! in less wall time than draining the queue one lane at a time. Emits
//! `BENCH_serve.json` (queries/sec, p50/p99 time-to-ε) for the CI perf
//! gate (`tools/bench_gate.py --kind serve`).

use diter::bench_harness::{bench_header, bench_json_dir, fmt_secs, Json, Table};
use diter::coordinator::{DistributedConfig, Query, QueryState, ServeConfig, ServeEngine};
use diter::graph::{power_law_web_graph, ChurnModel, MutableDigraph, MutationStream};
use diter::partition::Partition;
use diter::prng::Xoshiro256pp;
use std::time::{Duration, Instant};

/// Serve `queries` PPR queries through `lanes` concurrent lanes with a
/// churn batch after every other completion. Returns (wall seconds,
/// sorted time-to-ε samples).
fn run(n: usize, k: usize, lanes: usize, queries: usize, eps: f64, seed: u64) -> (f64, Vec<f64>) {
    let g = power_law_web_graph(n, 6, 0.1, seed);
    let mg = MutableDigraph::from_digraph(&g, n);
    let cfg = DistributedConfig::new(Partition::contiguous(n, k).unwrap())
        .with_tol(1e-9)
        .with_seed(seed);
    let serve_cfg = ServeConfig {
        queue_cap: queries,
        default_eps: eps,
        ..Default::default()
    };
    let mut serve = ServeEngine::new(mg, 0.85, true, cfg, serve_cfg, lanes).unwrap();
    let mut rng = Xoshiro256pp::seed_from_u64(seed ^ 0x5EED);
    for _ in 0..queries {
        let seeds = [rng.below(n), rng.below(n)];
        serve
            .submit(Query::ppr(&seeds, 0.85, eps))
            .expect("queue sized for the full load");
    }
    let mut churn = MutationStream::new(ChurnModel::RandomRewire, seed ^ 0xC0FFEE);
    let mut times = Vec::with_capacity(queries);
    let mut since_churn = 0usize;
    let t0 = Instant::now();
    let deadline = t0 + Duration::from_secs(300);
    while times.len() < queries && Instant::now() < deadline {
        for done in serve.poll().unwrap() {
            assert_eq!(done.state, QueryState::Served, "no deadlines configured");
            times.push(done.time_to_eps_secs.unwrap_or(0.0));
            since_churn += 1;
            if since_churn >= 2 {
                since_churn = 0;
                let batch = churn.next_batch(serve.engine().graph(), 12);
                serve.apply_mutations(&batch).unwrap();
            }
        }
        std::thread::sleep(Duration::from_micros(100));
    }
    let wall = t0.elapsed().as_secs_f64();
    assert_eq!(times.len(), queries, "every query must be served");
    serve.finish().unwrap();
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    (wall, times)
}

fn pct(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() as f64 - 1.0) * p).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

fn main() {
    bench_header(
        "serve_throughput",
        "multi-lane query serving vs sequential one-query-at-a-time, churn underneath",
    );
    let n = std::env::var("DITER_BENCH_N")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(2_000usize);
    let k = 3usize;
    let lanes = 3usize;
    let queries = 12usize;
    let eps = 1e-6;
    let seed = 17u64;
    println!("graph: {n} nodes, K={k}, {queries} PPR queries, ε={eps:.0e}\n");

    let (seq_wall, seq_times) = run(n, k, 1, queries, eps, seed);
    let (bat_wall, bat_times) = run(n, k, lanes, queries, eps, seed);
    let speedup = seq_wall / bat_wall.max(1e-9);
    let seq_qps = queries as f64 / seq_wall.max(1e-9);
    let bat_qps = queries as f64 / bat_wall.max(1e-9);

    let mut table = Table::new(&["config", "wall", "queries/s", "p50 tte", "p99 tte"]);
    table.row(&[
        "sequential (1 lane)".into(),
        fmt_secs(seq_wall),
        format!("{seq_qps:.2}"),
        fmt_secs(pct(&seq_times, 0.50)),
        fmt_secs(pct(&seq_times, 0.99)),
    ]);
    table.row(&[
        format!("batched ({lanes} lanes)"),
        fmt_secs(bat_wall),
        format!("{bat_qps:.2}"),
        fmt_secs(pct(&bat_times, 0.50)),
        fmt_secs(pct(&bat_times, 0.99)),
    ]);
    print!("{}", table.render());
    println!("\nbatched vs sequential: {speedup:.2}x");

    let bench_env = std::env::var("DITER_BENCH_ENV").unwrap_or_else(|_| "local".into());
    let json = Json::new()
        .int_field("schema", 1)
        .str_field("bench", "serve_throughput")
        .bool_field("measured", true)
        .str_field("environment", &bench_env)
        .int_field("n", n as u64)
        .int_field("k", k as u64)
        .int_field("lanes", lanes as u64)
        .int_field("queries", queries as u64)
        .num_field("eps", eps)
        .num_field("sequential_wall_secs", seq_wall)
        .num_field("batched_wall_secs", bat_wall)
        .num_field("sequential_queries_per_sec", seq_qps)
        .num_field("batched_queries_per_sec", bat_qps)
        .num_field("p50_time_to_eps_secs", pct(&bat_times, 0.50))
        .num_field("p99_time_to_eps_secs", pct(&bat_times, 0.99))
        .num_field("sequential_p50_time_to_eps_secs", pct(&seq_times, 0.50))
        .num_field("sequential_p99_time_to_eps_secs", pct(&seq_times, 0.99))
        .num_field("batched_vs_sequential_speedup", speedup);
    let path = bench_json_dir().join("BENCH_serve.json");
    json.write(&path).expect("write BENCH_serve.json");
    println!("\nwrote {}", path.display());

    assert!(
        speedup > 1.0,
        "lanes must beat one-at-a-time serving (got {speedup:.2}x)"
    );
}
