//! Ablation: the continuous version of the paper's Fig 1 → Fig 3
//! progression — sweep the inter-block coupling strength and chart the
//! distributed gain. Expected shape: gain ≈ K at zero coupling, decaying
//! towards ≈1 as coupling approaches the within-block weight.

use diter::bench_harness::{bench_header, Table};
use diter::coordinator::sim::{simulate_v1, SimConfig};
use diter::graph::block_coupled_matrix;
use diter::linalg::vec_ops::dist1;
use diter::partition::Partition;
use diter::solver::FixedPointProblem;
use diter::sparse::SparseMatrix;

fn main() {
    bench_header(
        "ablation_coupling",
        "distributed gain vs inter-block coupling (lockstep V1, K=4, N=128)",
    );
    let n = 128;
    let k = 4;
    let tol = 1e-8;
    let mut table = Table::new(&["coupling", "cut-fraction", "cost-1pid", "cost-4pids", "gain"]);
    for coupling in [0.0, 0.05, 0.1, 0.2, 0.3, 0.4] {
        let p = block_coupled_matrix(n, k, 0.45, coupling, 5, 9);
        let problem =
            FixedPointProblem::new(SparseMatrix::from_csr(p.clone()), vec![1.0; n]).unwrap();
        let exact = problem.exact_solution().unwrap();
        let part = Partition::contiguous(n, k).unwrap();
        let cut = part.cut_fraction(&p);
        let reach = |snaps: &[diter::coordinator::sim::Snapshot]| {
            snaps
                .iter()
                .find(|s| dist1(&s.x, &exact) < tol)
                .map(|s| s.cost)
        };
        let multi = simulate_v1(
            &problem,
            &SimConfig {
                partition: part,
                sweeps_per_share: 2,
                max_cost: 2_000,
                switch_at: None,
            },
        )
        .unwrap();
        let single = simulate_v1(
            &problem,
            &SimConfig {
                partition: Partition::contiguous(n, 1).unwrap(),
                sweeps_per_share: 2,
                max_cost: 2_000,
                switch_at: None,
            },
        )
        .unwrap();
        let (c1, ck) = match (reach(&single), reach(&multi)) {
            (Some(a), Some(b)) => (a, b),
            _ => {
                table.row(&[
                    format!("{coupling}"),
                    format!("{cut:.3}"),
                    "-".into(),
                    "-".into(),
                    "n/a".into(),
                ]);
                continue;
            }
        };
        // per-processor work gain: each of the K PIDs sweeps N/K rows
        let gain = k as f64 * c1 / ck.max(1.0);
        table.row(&[
            format!("{coupling}"),
            format!("{cut:.3}"),
            format!("{c1}"),
            format!("{ck}"),
            format!("{gain:.2}x"),
        ]);
    }
    print!("{}", table.render());
    println!("\n(gain ≈ K at coupling 0, collapsing as the cut fraction grows — Fig 1→3)");
}
