//! Bench: the paper's announced target workload (§5/§6) — PageRank on a
//! synthetic power-law web graph, V2 distributed D-iteration, scaling the
//! number of PIDs. Reports wall time, work, parallel cost, throughput and
//! transport volume per K, plus the sequential baselines.

use std::time::Duration;

use diter::bench_harness::{bench_header, fmt_secs, Table};
use diter::coordinator::{v2, DistributedConfig};
use diter::graph::{pagerank_system, power_law_web_graph};
use diter::metrics::Stopwatch;
use diter::partition::Partition;
use diter::solver::{DIteration, FixedPointProblem, SequenceKind, SolveOptions, Solver};

fn main() {
    bench_header(
        "pagerank_scale",
        "V2 distributed PageRank on a power-law web graph, K = 1..8 PIDs",
    );
    let n = std::env::var("DITER_BENCH_N")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(20_000usize);
    let tol = 1e-9;
    let g = power_law_web_graph(n, 8, 0.1, 7);
    println!(
        "graph: {} nodes, {} edges, {} dangling; tol {tol:.0e}\n",
        g.n(),
        g.m(),
        g.dangling_nodes().len()
    );
    let sys = pagerank_system(&g, 0.85, false).unwrap();
    let problem = FixedPointProblem::new(sys.matrix.clone(), sys.b.clone()).unwrap();

    // sequential baselines
    let mut table = Table::new(&[
        "scheme", "K", "wall", "upd/s", "parallel-cost", "msgs", "MB-sent", "residual",
    ]);
    for (name, solver) in [
        ("diter-seq", DIteration::fluid_cyclic()),
        ("diter-greedy", DIteration::greedy()),
    ] {
        let sw = Stopwatch::start();
        let sol = solver
            .solve(
                &problem,
                &SolveOptions {
                    tol,
                    max_cost: 100_000.0,
                    trace_every: 0.0,
                    exact: None,
                },
            )
            .unwrap();
        let wall = sw.elapsed_secs();
        let updates = sol.cost * n as f64;
        table.row(&[
            name.into(),
            "1".into(),
            fmt_secs(wall),
            format!("{:.2e}", updates / wall),
            format!("{:.1}", sol.cost),
            "-".into(),
            "-".into(),
            format!("{:.1e}", sol.residual),
        ]);
    }

    let mut wall1 = None;
    for k in [1usize, 2, 4, 8] {
        let mut cfg = DistributedConfig::new(Partition::contiguous(n, k).unwrap())
            .with_tol(tol)
            .with_sequence(SequenceKind::GreedyMaxFluid)
            .with_seed(5);
        cfg.max_wall = Duration::from_secs(120);
        let sol = v2::solve_v2(&problem, &cfg).unwrap();
        assert!(sol.converged, "K={k} did not converge");
        if k == 1 {
            wall1 = Some(sol.wall_secs);
        }
        table.row(&[
            "diter-v2".into(),
            k.to_string(),
            fmt_secs(sol.wall_secs),
            format!("{:.2e}", sol.updates_per_sec()),
            format!("{:.1}", sol.cost),
            sol.metrics["msgs_sent"].to_string(),
            format!("{:.2}", sol.metrics["bytes_sent"] as f64 / 1e6),
            format!("{:.1e}", sol.residual),
        ]);
    }
    print!("{}", table.render());
    if let Some(w1) = wall1 {
        println!("\n(speedup columns are wall-clock vs K=1: report shape, not absolutes —");
        println!(" K=1 wall {} on this host)", fmt_secs(w1));
    }
}
