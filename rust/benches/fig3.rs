//! Bench: regenerate paper Figure 3 — A(3), 2 PIDs, stronger coupling
//! (one extra entry at (2,4)). Expected shape: "no longer any significant
//! gain" for the distributed run.

use diter::bench_harness::bench_header;
use diter::figures::{figure_gain, render_figure};

fn main() {
    bench_header(
        "fig3",
        "Figure 3: 2 PIDs on A(3) (strong coupling) — error vs iteration",
    );
    print!("{}", render_figure(3, 20).expect("figure 3"));
    let g3 = figure_gain(3, 1e-8, 400)
        .expect("gain")
        .expect("tolerance reached");
    let g1 = figure_gain(1, 1e-8, 400).expect("gain").unwrap();
    println!("\nper-processor gain at 1e-8: fig3 {g3:.2}x vs fig1 {g1:.2}x (paper: gain collapses)");
}
