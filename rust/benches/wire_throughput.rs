//! Perf bench: the TCP wire transport's zero-copy fast path
//! (DESIGN.md §8.8) over loopback sockets.
//!
//!  * parcel throughput per parcel size (16 / 256 / 4096 coordinates):
//!    parcels/sec and payload bytes/sec through the full cycle — pooled
//!    encode, vectored flush, ring read, in-place pooled decode, commit,
//!    ACK
//!  * syscall batching: writev calls per 1 000 parcels (hub-wide, both
//!    directions — smaller is better)
//!  * allocator traffic: heap allocations per parcel in steady state,
//!    from the installed [`CountingAlloc`] (the §8.8 target is 0)
//!  * batched vs unbatched: the same traffic under the default
//!    [`FlushPolicy`] vs a flush-per-frame policy (`max_frames = 1`),
//!    i.e. the PR 6 behaviour — the speedup the batching fast path buys
//!
//! Emits `BENCH_wire.json` into `DITER_BENCH_JSON_DIR` (default `.`).
//! The committed copy at the repo root is the baseline
//! `tools/bench_gate.py --kind wire` compares against. Env knobs:
//! `DITER_BENCH_ENV` (recorded measurement environment),
//! `DITER_BENCH_WIRE_HOPS` (measured parcel hops per configuration).

use std::time::{Duration, Instant};

use diter::bench_harness::{bench_header, bench_json_dir, fmt_secs, Json, Table};
use diter::coordinator::WorkerMsg;
use diter::perf::CountingAlloc;
use diter::transport::{
    BusConfig, FlushPolicy, Received, Transport, WireEndpoint, WireHub,
};

// Count every heap allocation the bench makes — allocs/parcel turns the
// "steady-state wire traffic is allocation-free" claim into a number.
#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc::new();

/// Parcels kept circulating between the two endpoints — enough to keep
/// frames queued at every flush decision without overrunning the pools.
const PARCELS: usize = 8;

/// One configuration's steady-state run.
struct WireRun {
    coords: usize,
    parcels: u64,
    wall_secs: f64,
    bytes: u64,
    writev_calls: u64,
    allocations: u64,
}

impl WireRun {
    fn parcels_per_sec(&self) -> f64 {
        self.parcels as f64 / self.wall_secs.max(1e-9)
    }

    fn bytes_per_sec(&self) -> f64 {
        self.bytes as f64 / self.wall_secs.max(1e-9)
    }

    /// Vectored-write syscalls per 1 000 parcel hops, hub-wide (data
    /// frames and ACKs, both directions). Perfect batching drives this
    /// far below 2 000 (one data write + one ACK write per hop).
    fn syscalls_per_kparcel(&self) -> f64 {
        self.writev_calls as f64 * 1e3 / self.parcels.max(1) as f64
    }

    fn allocs_per_parcel(&self) -> f64 {
        self.allocations as f64 / self.parcels.max(1) as f64
    }

    fn to_json(&self) -> Json {
        Json::new()
            .int_field("coords", self.coords as u64)
            .int_field("parcels", self.parcels)
            .num_field("wall_secs", self.wall_secs)
            .num_field("parcels_per_sec", self.parcels_per_sec())
            .num_field("bytes_per_sec", self.bytes_per_sec())
            .num_field("syscalls_per_kparcel", self.syscalls_per_kparcel())
            .int_field("allocations", self.allocations)
            .num_field("allocs_per_parcel", self.allocs_per_parcel())
    }
}

/// Drain everything ripe at `e`, commit, echo the payload back — the
/// received columns flow straight back out through the pooled encode.
fn bounce(e: &mut WireEndpoint<WorkerMsg>, dest: usize, approx: usize) -> usize {
    let mut moved = 0;
    while let Some(Received {
        from,
        seq,
        mass,
        payload,
    }) = e.try_recv_uncommitted()
    {
        e.commit(from, seq, mass);
        Transport::send(e, dest, payload, mass, approx).expect("echo");
        moved += 1;
    }
    e.flush();
    e.collect_acks();
    moved
}

/// Circulate `PARCELS` parcels of `coords` coordinates under `policy`:
/// warm every pool to its high-water mark, then measure `hops` hops.
fn run_wire(coords: usize, policy: FlushPolicy, warm_hops: usize, hops: usize) -> WireRun {
    let cfg = BusConfig {
        flush: policy,
        ..BusConfig::default()
    };
    let hub = WireHub::<WorkerMsg>::loopback(&cfg, &[]);
    let mut a = hub.add_endpoint(0).expect("endpoint 0");
    let mut b = hub.add_endpoint(1).expect("endpoint 1");
    for s in 0..PARCELS {
        let parcel = WorkerMsg::Fluid {
            epoch: 1,
            coords: (0..coords as u32).map(|i| i * 3 + s as u32).collect(),
            mass: (0..coords).map(|i| 1.0 / (coords * (i + 1)) as f64).collect(),
            qids: vec![],
        };
        Transport::send(&mut a, 1, parcel, 1.0, coords).expect("prime send");
    }
    a.flush();

    let spin = |a: &mut WireEndpoint<WorkerMsg>, b: &mut WireEndpoint<WorkerMsg>, goal: usize| {
        let deadline = Instant::now() + Duration::from_secs(60);
        let mut moved = 0;
        while moved < goal {
            let m = bounce(a, 1, coords) + bounce(b, 0, coords);
            moved += m;
            if m == 0 {
                assert!(Instant::now() < deadline, "wire bench stalled at {moved} hops");
                std::thread::yield_now();
            }
        }
        moved
    };
    spin(&mut a, &mut b, warm_hops);

    let metrics = a.metrics();
    let bytes0 = metrics.get("wire_bytes_sent");
    let writev0 = metrics.get("wire_writev_calls");
    let a0 = CountingAlloc::thread_allocations();
    let t0 = Instant::now();
    let moved = spin(&mut a, &mut b, hops);
    let wall_secs = t0.elapsed().as_secs_f64();
    WireRun {
        coords,
        parcels: moved as u64,
        wall_secs,
        bytes: metrics.get("wire_bytes_sent") - bytes0,
        writev_calls: metrics.get("wire_writev_calls") - writev0,
        allocations: CountingAlloc::thread_allocations() - a0,
    }
}

fn main() {
    bench_header("wire", "TCP wire transport zero-copy fast path");
    let bench_env = std::env::var("DITER_BENCH_ENV").unwrap_or_else(|_| "local".into());
    let hops: usize = std::env::var("DITER_BENCH_WIRE_HOPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(20_000);

    let mut table = Table::new(&[
        "config",
        "parcels/s",
        "MB/s",
        "syscalls/kparcel",
        "allocs/parcel",
        "wall",
    ]);
    let mut row = |name: &str, r: &WireRun| {
        table.row(&[
            name.into(),
            format!("{:.2e}", r.parcels_per_sec()),
            format!("{:.1}", r.bytes_per_sec() / 1e6),
            format!("{:.1}", r.syscalls_per_kparcel()),
            format!("{:.3}", r.allocs_per_parcel()),
            fmt_secs(r.wall_secs),
        ]);
    };

    // --- throughput per parcel size, default (batched) policy -----------
    let warm = (hops / 10).max(500);
    let small = run_wire(16, FlushPolicy::default(), warm, hops);
    row("batched, 16 coords", &small);
    let medium = run_wire(256, FlushPolicy::default(), warm, hops);
    row("batched, 256 coords", &medium);
    let large = run_wire(4096, FlushPolicy::default(), warm, hops / 4);
    row("batched, 4096 coords", &large);

    // --- batched vs unbatched (flush-per-frame, the PR 6 behaviour) -----
    let unbatched = run_wire(
        256,
        FlushPolicy {
            max_bytes: 1,
            max_frames: 1,
            deadline: Duration::ZERO,
        },
        warm,
        hops,
    );
    row("unbatched, 256 coords", &unbatched);
    let speedup = medium.parcels_per_sec() / unbatched.parcels_per_sec().max(1e-9);
    print!("{}", table.render());
    println!(
        "\nbatched vs unbatched: {speedup:.2}x parcels/sec \
         ({:.1} vs {:.1} syscalls/kparcel, 256-coord parcels)",
        medium.syscalls_per_kparcel(),
        unbatched.syscalls_per_kparcel()
    );

    let json = Json::new()
        .int_field("schema", 1)
        .str_field("bench", "wire")
        .bool_field("measured", true)
        .str_field("environment", &bench_env)
        .int_field("parcels_in_flight", PARCELS as u64)
        .int_field("hops", hops as u64)
        .obj_field("small", small.to_json())
        .obj_field("batched", medium.to_json())
        .obj_field("large", large.to_json())
        .obj_field("unbatched", unbatched.to_json())
        .num_field("batched_vs_unbatched_speedup", speedup);
    let path = bench_json_dir().join("BENCH_wire.json");
    json.write(&path).expect("write BENCH_wire.json");
    println!("wrote {}", path.display());
}
