//! Bench: time-to-converge under a hotspot burst with one slowed PID —
//! fixed-K (static and shed-only adaptive) vs the elastic worker pool.
//!
//! Scenario: K = 2 streaming workers, PID 0 throttled to a fixed
//! updates/sec budget, a flash-crowd (`HotSpotBurst`) mutation batch
//! landing mid-run. Fixed-K leaves half the coordinate space pinned to
//! the straggler; the shed-only rebalancer can move load to PID 1 but
//! the pool stays at two workers; the elastic pool **spawns** workers to
//! absorb the straggler's share (and can retire them once idle), so its
//! time-to-converge should approach the unthrottled budget. Emits
//! `BENCH_elastic.json` for the CI perf gate (`tools/bench_gate.py
//! --kind elastic`).

use diter::bench_harness::{bench_header, bench_json_dir, fmt_secs, Json, Table};
use diter::coordinator::{
    AdaptiveConfig, DistributedConfig, ElasticConfig, PoolStats, StreamingEngine,
};
use diter::graph::{power_law_web_graph, ChurnModel, MutableDigraph, MutationStream};
use diter::partition::Partition;
use diter::solver::SequenceKind;
use std::time::Duration;

/// One full scenario run: initial converge + one hotspot batch.
/// Returns (total wall seconds, final residual, pool stats).
fn run(n: usize, cfg: DistributedConfig, seed: u64) -> (f64, f64, PoolStats) {
    let g = power_law_web_graph(n, 6, 0.1, seed);
    let mg = MutableDigraph::from_digraph(&g, n);
    let mut eng = StreamingEngine::new(mg, 0.85, true, cfg).unwrap();
    let init = eng.converge().unwrap();
    assert!(init.solution.converged, "initial solve must converge");
    let mut stream = MutationStream::new(ChurnModel::HotSpotBurst { burst: 32 }, seed ^ 0xB00);
    let batch = stream.next_batch(eng.graph(), 48);
    let report = eng.apply_batch(&batch).unwrap();
    assert!(report.solution.converged, "hotspot epoch must reconverge");
    let wall = init.solution.wall_secs + report.solution.wall_secs;
    let residual = report.solution.residual;
    let stats = eng.pool_stats();
    eng.finish().unwrap();
    (wall, residual, stats)
}

fn main() {
    bench_header(
        "elastic_pool",
        "hotspot burst with one slowed PID: fixed-K vs elastic worker pool (K0=2)",
    );
    let n = std::env::var("DITER_BENCH_N")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(3_000usize);
    let k = 2usize;
    let tol = 1e-9;
    let straggler_ups = 20_000.0;
    let seed = 11u64;
    println!("graph: {n} nodes, K0={k}, straggler 0 @ {straggler_ups:.0} upd/s, tol {tol:.0e}\n");

    let base = || {
        let mut cfg = DistributedConfig::new(Partition::contiguous(n, k).unwrap())
            .with_tol(tol)
            .with_seed(seed)
            .with_sequence(SequenceKind::GreedyMaxFluid)
            .with_straggler(0, straggler_ups);
        cfg.max_wall = Duration::from_secs(600);
        cfg
    };

    let (fixed_wall, fixed_res, _) = run(n, base(), seed);
    let (adaptive_wall, adaptive_res, _) = run(
        n,
        base().with_adaptive(AdaptiveConfig {
            interval: Duration::from_millis(20),
            ..Default::default()
        }),
        seed,
    );
    let elastic_cfg = ElasticConfig {
        max_workers: 6,
        spawn_threshold: 0.5,
        retire_idle: Duration::from_secs(30),
        interval: Duration::from_millis(15),
        ..Default::default()
    };
    let (elastic_wall, elastic_res, stats) = run(n, base().with_elastic(elastic_cfg), seed);

    let vs_fixed = fixed_wall / elastic_wall.max(1e-9);
    let vs_adaptive = adaptive_wall / elastic_wall.max(1e-9);
    let mut table = Table::new(&["config", "wall", "residual", "vs-elastic"]);
    table.row(&[
        "fixed-K static".into(),
        fmt_secs(fixed_wall),
        format!("{fixed_res:.1e}"),
        format!("{vs_fixed:.2}x slower"),
    ]);
    table.row(&[
        "fixed-K adaptive".into(),
        fmt_secs(adaptive_wall),
        format!("{adaptive_res:.1e}"),
        format!("{vs_adaptive:.2}x slower"),
    ]);
    table.row(&[
        format!("elastic (peak {} workers)", stats.peak_live),
        fmt_secs(elastic_wall),
        format!("{elastic_res:.1e}"),
        "1.00x".into(),
    ]);
    print!("{}", table.render());
    println!(
        "\npool: spawned {} retired {} sheds {} peak {}",
        stats.spawned, stats.retired, stats.sheds, stats.peak_live
    );

    let bench_env = std::env::var("DITER_BENCH_ENV").unwrap_or_else(|_| "local".into());
    let json = Json::new()
        .int_field("schema", 1)
        .str_field("bench", "elastic_pool")
        .bool_field("measured", true)
        .str_field("environment", &bench_env)
        .int_field("n", n as u64)
        .int_field("k_fixed", k as u64)
        .num_field("tol", tol)
        .num_field("straggler_updates_per_sec", straggler_ups)
        .num_field("fixed_time_to_converge_secs", fixed_wall)
        .num_field("adaptive_time_to_converge_secs", adaptive_wall)
        .num_field("elastic_time_to_converge_secs", elastic_wall)
        .num_field("elastic_vs_fixed_speedup", vs_fixed)
        .num_field("elastic_vs_adaptive_speedup", vs_adaptive)
        .int_field("pool_spawned", stats.spawned)
        .int_field("pool_retired", stats.retired)
        .int_field("pool_peak_live", stats.peak_live as u64);
    let path = bench_json_dir().join("BENCH_elastic.json");
    json.write(&path).expect("write BENCH_elastic.json");
    println!("\nwrote {}", path.display());

    assert!(
        stats.spawned >= 1,
        "the elastic run must actually have spawned a worker"
    );
    assert!(
        vs_fixed > 1.0,
        "elastic must beat fixed-K time-to-converge under the hotspot \
         scenario (got {vs_fixed:.2}x)"
    );
    println!("elastic beats fixed-K: {vs_fixed:.2}x (vs shed-only adaptive: {vs_adaptive:.2}x)");
}
