//! Bench: time-to-converge with one artificially slowed PID — static
//! partition vs live adaptive repartitioning (§4.3 operationalized).
//!
//! One PID is throttled to a fixed updates/sec budget (a simulated slow or
//! oversubscribed machine). Static partitioning leaves it holding 1/K of
//! the coordinates, so the whole solve waits on it; with `--adaptive` the
//! leader detects the straggler from the windowed per-PID rates and hands
//! most of its Ω to faster PIDs mid-solve. Expected shape: the adaptive
//! run's wall time approaches the unthrottled solve as the straggler's
//! share shrinks, while the static run degrades linearly with the
//! throttle.

use diter::bench_harness::{bench_header, bench_json_dir, fmt_secs, Json, Table};
use diter::coordinator::{v2, AdaptiveConfig, DistributedConfig};
use diter::graph::{pagerank_system, power_law_web_graph};
use diter::partition::Partition;
use diter::solver::{FixedPointProblem, SequenceKind};
use std::time::Duration;

fn main() {
    bench_header(
        "adaptive_straggler",
        "time-to-converge with one slowed PID: static vs adaptive (PageRank, K=4)",
    );
    let n = std::env::var("DITER_BENCH_N")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(4_000usize);
    let k = 4usize;
    let tol = 1e-9;
    let g = power_law_web_graph(n, 8, 0.1, 7);
    let sys = pagerank_system(&g, 0.85, true).unwrap();
    let problem = FixedPointProblem::new(sys.matrix.clone(), sys.b.clone()).unwrap();
    println!("graph: {} nodes, {} edges; tol {tol:.0e}\n", n, g.m());

    let base = |straggler_ups: Option<f64>| {
        let mut cfg = DistributedConfig::new(Partition::contiguous(n, k).unwrap())
            .with_tol(tol)
            .with_seed(5)
            .with_sequence(SequenceKind::GreedyMaxFluid);
        cfg.max_wall = Duration::from_secs(300);
        if let Some(ups) = straggler_ups {
            cfg = cfg.with_straggler(0, ups);
        }
        cfg
    };

    let unthrottled = v2::solve_v2(&problem, &base(None)).unwrap();
    assert!(unthrottled.converged);
    println!(
        "unthrottled baseline: {} ({} updates)\n",
        fmt_secs(unthrottled.wall_secs),
        unthrottled.total_updates
    );

    let mut table = Table::new(&[
        "straggler-upd/s",
        "static-wall",
        "adaptive-wall",
        "speedup",
        "handoffs",
        "moved-coords",
        "static-res",
        "adaptive-res",
    ]);
    let mut last_speedup = 0.0;
    let mut throttles = Vec::new();
    let mut static_walls = Vec::new();
    let mut adaptive_walls = Vec::new();
    let mut speedups = Vec::new();
    let mut handoffs_total = 0u64;
    for &ups in &[200_000.0, 50_000.0, 20_000.0] {
        let static_sol = v2::solve_v2(&problem, &base(Some(ups))).unwrap();
        assert!(static_sol.converged, "static run must still converge");
        let adaptive_cfg = base(Some(ups)).with_adaptive(AdaptiveConfig {
            interval: Duration::from_millis(25),
            ..Default::default()
        });
        let adaptive_sol = v2::solve_v2(&problem, &adaptive_cfg).unwrap();
        assert!(adaptive_sol.converged, "adaptive run must converge");
        last_speedup = static_sol.wall_secs / adaptive_sol.wall_secs.max(1e-9);
        throttles.push(ups);
        static_walls.push(static_sol.wall_secs);
        adaptive_walls.push(adaptive_sol.wall_secs);
        speedups.push(last_speedup);
        handoffs_total += adaptive_sol.metrics["handoffs_total"];
        table.row(&[
            format!("{ups:.0}"),
            fmt_secs(static_sol.wall_secs),
            fmt_secs(adaptive_sol.wall_secs),
            format!("{last_speedup:.2}x"),
            adaptive_sol.metrics["handoffs_total"].to_string(),
            adaptive_sol.metrics["handoff_coords"].to_string(),
            format!("{:.1e}", static_sol.residual),
            format!("{:.1e}", adaptive_sol.residual),
        ]);
    }
    print!("{}", table.render());

    let bench_env = std::env::var("DITER_BENCH_ENV").unwrap_or_else(|_| "local".into());
    let json = Json::new()
        .int_field("schema", 1)
        .str_field("bench", "adaptive_straggler")
        .bool_field("measured", true)
        .str_field("environment", &bench_env)
        .int_field("n", n as u64)
        .int_field("k", k as u64)
        .num_field("tol", tol)
        .num_field("unthrottled_wall_secs", unthrottled.wall_secs)
        .num_field(
            "unthrottled_updates_per_sec",
            unthrottled.updates_per_sec(),
        )
        .arr_num_field("straggler_updates_per_sec", &throttles)
        .arr_num_field("static_time_to_reconverge_secs", &static_walls)
        .arr_num_field("adaptive_time_to_reconverge_secs", &adaptive_walls)
        .arr_num_field("adaptive_vs_static_speedup", &speedups)
        .int_field("handoffs_total", handoffs_total);
    let path = bench_json_dir().join("BENCH_adaptive.json");
    json.write(&path).expect("write BENCH_adaptive.json");
    println!("\nwrote {}", path.display());

    assert!(
        last_speedup > 1.0,
        "adaptive repartitioning must beat the static partition on the \
         hardest straggler (speedup {last_speedup:.2}x)"
    );
    println!("adaptive beats static on the 20k upd/s straggler: {last_speedup:.2}x");
}
