//! Ablation (§3.3): fluid regrouping/coalescing — "the fluid transmission
//! can be delayed and regrouped so that this quantity is not too small".
//! Sweeps the coalescing mass floor and measures messages vs convergence
//! cost. Expected shape: regrouping cuts messages by orders of magnitude
//! at essentially no cost in parallel work, until the floor gets so large
//! it delays convergence.

use std::time::Duration;

use diter::bench_harness::{bench_header, fmt_secs, Table};
use diter::coordinator::{v2, DistributedConfig};
use diter::graph::{pagerank_system, power_law_web_graph};
use diter::partition::Partition;
use diter::solver::{FixedPointProblem, SequenceKind};
use diter::transport::CoalescePolicy;

fn main() {
    bench_header(
        "ablation_regroup",
        "coalescing floor sweep on web-graph PageRank (N=4000, K=4)",
    );
    let n = 4_000;
    let g = power_law_web_graph(n, 6, 0.1, 13);
    let sys = pagerank_system(&g, 0.85, false).unwrap();
    let problem = FixedPointProblem::new(sys.matrix.clone(), sys.b.clone()).unwrap();
    let mut table = Table::new(&[
        "min_mass", "msgs", "fluid-entries/msg", "MB-sent", "wall", "parallel-cost", "converged",
    ]);
    for min_mass in [0.0, 1e-12, 1e-9, 1e-6, 1e-4, 1e-2] {
        let mut cfg = DistributedConfig::new(Partition::contiguous(n, 4).unwrap())
            .with_tol(1e-9)
            .with_sequence(SequenceKind::GreedyMaxFluid)
            .with_seed(17);
        cfg.coalesce = CoalescePolicy {
            min_mass,
            max_entries: 4096,
        };
        cfg.max_wall = Duration::from_secs(60);
        let sol = v2::solve_v2(&problem, &cfg).unwrap();
        let msgs = sol.metrics["msgs_sent"].max(1);
        let bytes = sol.metrics["bytes_sent"];
        table.row(&[
            format!("{min_mass:.0e}"),
            msgs.to_string(),
            format!("{:.1}", (bytes.saturating_sub(16 * msgs)) as f64 / 16.0 / msgs as f64),
            format!("{:.2}", bytes as f64 / 1e6),
            fmt_secs(sol.wall_secs),
            format!("{:.1}", sol.cost),
            sol.converged.to_string(),
        ]);
    }
    print!("{}", table.render());
}
