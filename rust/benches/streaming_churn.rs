//! Bench: the streaming engine under sustained churn — steady-state
//! updates/sec and time-to-reconverge per mutation batch, against a full
//! V2 restart on every batch (the baseline an offline system pays) — plus
//! the **kernel head-to-head**: the same churn workload driven through the
//! partition-local block kernel and through the pre-refactor global-walk
//! kernel, in the same binary, recording the diffusions/sec ratio.
//!
//! Emits `BENCH_stream.json` (machine-readable: updates/sec,
//! time-to-reconverge, diffusions/sec per kernel, and the local/global
//! speedup) into `DITER_BENCH_JSON_DIR` (default `.`). The committed copy
//! at the repo root is the perf-trajectory baseline the CI gate
//! (`tools/bench_gate.py`) compares against.
//!
//! Env knobs: `DITER_BENCH_N` (graph size), `DITER_BENCH_JSON_DIR`
//! (relative paths resolve against the workspace root, not cargo's
//! package-root cwd), `DITER_BENCH_ENV` (recorded as the measurement
//! environment), `DITER_BENCH_ASSERT_SPEEDUP` (fail unless local ≥ this
//! × global).

use std::time::Duration;

use diter::bench_harness::{bench_header, bench_json_dir, fmt_secs, Json, Table};
use diter::coordinator::{v2, DistributedConfig, KernelKind, StreamingEngine};
use diter::graph::{power_law_web_graph, ChurnModel, MutableDigraph, MutationStream};
use diter::partition::Partition;
use diter::solver::SequenceKind;

const K: usize = 4;
const TOL: f64 = 1e-9;

fn base_cfg(n: usize, kernel: KernelKind) -> DistributedConfig {
    let mut cfg = DistributedConfig::new(Partition::contiguous(n, K).unwrap())
        .with_tol(TOL)
        .with_seed(5)
        .with_sequence(SequenceKind::GreedyMaxFluid)
        .with_kernel(kernel);
    cfg.max_wall = Duration::from_secs(300);
    cfg
}

/// One kernel's run over the shared churn workload.
struct KernelStats {
    init_updates: u64,
    init_wall: f64,
    reconverge_walls: Vec<f64>,
    epoch_updates: u64,
    epoch_wall: f64,
}

impl KernelStats {
    /// Diffusions/sec over the initial cold solve — the headline kernel
    /// throughput (scalar diffusions == scalar updates in this scheme).
    fn init_diffusions_per_sec(&self) -> f64 {
        self.init_updates as f64 / self.init_wall.max(1e-9)
    }

    fn epoch_diffusions_per_sec(&self) -> f64 {
        self.epoch_updates as f64 / self.epoch_wall.max(1e-9)
    }

    fn reconverge_mean(&self) -> f64 {
        if self.reconverge_walls.is_empty() {
            return 0.0;
        }
        self.reconverge_walls.iter().sum::<f64>() / self.reconverge_walls.len() as f64
    }

    fn to_json(&self) -> Json {
        Json::new()
            .num_field("init_diffusions_per_sec", self.init_diffusions_per_sec())
            .num_field("epoch_diffusions_per_sec", self.epoch_diffusions_per_sec())
            .int_field("init_updates", self.init_updates)
            .num_field("init_wall_secs", self.init_wall)
            .num_field("reconverge_secs_mean", self.reconverge_mean())
            .arr_num_field("reconverge_secs", &self.reconverge_walls)
    }
}

/// Drive one engine (one kernel) through the head-to-head workload: cold
/// solve + `batches` rewire batches of `batch_size`. Streams are re-seeded
/// identically per kernel, and batches are generated against each engine's
/// own evolving graph — the graphs evolve identically, so both kernels see
/// the same mutation sequence.
fn run_kernel(n: usize, kernel: KernelKind, batches: usize, batch_size: usize) -> KernelStats {
    let g = power_law_web_graph(n, 8, 0.1, 7);
    let mg = MutableDigraph::from_digraph(&g, n);
    let mut engine = StreamingEngine::new(mg, 0.85, true, base_cfg(n, kernel)).expect("engine");
    let init = engine.converge().expect("initial solve");
    assert!(
        init.solution.converged,
        "[{}] initial solve must converge (residual {:.3e})",
        kernel.name(),
        init.solution.residual
    );
    let mut stream = MutationStream::new(ChurnModel::RandomRewire, 131);
    let mut walls = Vec::with_capacity(batches);
    let mut epoch_updates = 0u64;
    let mut epoch_wall = 0.0f64;
    for _ in 0..batches {
        let batch = stream.next_batch(engine.graph(), batch_size);
        let report = engine.apply_batch(&batch).expect("apply");
        assert!(
            report.solution.converged,
            "[{}] reconverge failed (residual {:.3e})",
            kernel.name(),
            report.solution.residual
        );
        walls.push(report.solution.wall_secs);
        epoch_updates += report.solution.total_updates;
        epoch_wall += report.solution.wall_secs;
    }
    engine.finish().expect("finish");
    KernelStats {
        init_updates: init.solution.total_updates,
        init_wall: init.solution.wall_secs,
        reconverge_walls: walls,
        epoch_updates,
        epoch_wall,
    }
}

fn main() {
    bench_header(
        "streaming_churn",
        "warm rebase vs cold restart under churn (web graph, V2, K=4) \
         + local-block vs global-walk kernel head-to-head",
    );
    let n = std::env::var("DITER_BENCH_N")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(10_000usize);
    let bench_env = std::env::var("DITER_BENCH_ENV").unwrap_or_else(|_| "local".into());
    let batches_per_size = 3usize;

    // ---- part 1: warm rebase vs cold restart (local kernel) -------------
    let g = power_law_web_graph(n, 8, 0.1, 7);
    println!("graph: {} nodes, {} edges; tol {TOL:.0e}\n", g.n(), g.m());
    let mg = MutableDigraph::from_digraph(&g, n);
    let cfg = base_cfg(n, KernelKind::LocalBlock);
    let cold_cfg = cfg.clone();

    let mut engine = StreamingEngine::new(mg, 0.85, true, cfg).expect("engine");
    let init = engine.converge().expect("initial solve");
    assert!(init.solution.converged, "initial solve must converge");
    println!(
        "initial solve: {} updates, {} ({:.2e} upd/s)\n",
        init.solution.total_updates,
        fmt_secs(init.solution.wall_secs),
        init.solution.total_updates as f64 / init.solution.wall_secs.max(1e-9)
    );

    let mut table = Table::new(&[
        "batch-size",
        "model",
        "reconverge",
        "warm-upd",
        "cold-wall",
        "cold-upd",
        "upd-saving",
        "steady-upd/s",
    ]);
    let mut stream = MutationStream::new(ChurnModel::RandomRewire, 31);
    let mut burst = MutationStream::new(ChurnModel::HotSpotBurst { burst: 64 }, 37);

    let mut warm_reconverge_secs = Vec::new();
    let mut upd_savings = Vec::new();
    for &batch_size in &[16usize, 64, 256, 1024] {
        let mut warm_wall = 0.0f64;
        let mut warm_upd = 0u64;
        let mut cold_wall = 0.0f64;
        let mut cold_upd = 0u64;
        for b in 0..batches_per_size {
            let batch = if b == batches_per_size - 1 {
                burst.next_batch(engine.graph(), batch_size)
            } else {
                stream.next_batch(engine.graph(), batch_size)
            };
            let report = engine.apply_batch(&batch).expect("apply");
            assert!(
                report.solution.converged,
                "batch size {batch_size}: residual {:.3e}",
                report.solution.residual
            );
            warm_wall += report.solution.wall_secs;
            warm_upd += report.solution.total_updates;
            let cold = v2::solve_v2(engine.problem(), &cold_cfg).expect("cold");
            assert!(cold.converged);
            cold_wall += cold.wall_secs;
            cold_upd += cold.total_updates;
        }
        let inv = 1.0 / batches_per_size as f64;
        warm_reconverge_secs.push(warm_wall * inv);
        upd_savings.push(cold_upd as f64 / warm_upd.max(1) as f64);
        table.row(&[
            batch_size.to_string(),
            "rewire+burst".into(),
            fmt_secs(warm_wall * inv),
            (warm_upd / batches_per_size as u64).to_string(),
            fmt_secs(cold_wall * inv),
            (cold_upd / batches_per_size as u64).to_string(),
            format!("{:.1}x", cold_upd as f64 / warm_upd.max(1) as f64),
            format!("{:.2e}", engine.steady_updates_per_sec()),
        ]);
    }
    print!("{}", table.render());

    let steady_upd_per_sec = engine.steady_updates_per_sec();
    let summary = engine.finish().expect("finish");
    println!(
        "\n{} epochs, {} mutations; whole-run mean {:.2e} upd/s; final residual {:.2e}",
        summary.epochs,
        summary.mutations_applied,
        summary.steady_updates_per_sec,
        summary.final_solution.residual
    );
    println!("(reconverge = mean wall-clock from batch application to total fluid < tol)\n");

    // ---- part 2: kernel head-to-head ------------------------------------
    println!("kernel head-to-head (same workload, same binary):");
    let local = run_kernel(n, KernelKind::LocalBlock, 4, 64);
    let global = run_kernel(n, KernelKind::GlobalWalk, 4, 64);
    let speedup = local.init_diffusions_per_sec() / global.init_diffusions_per_sec().max(1e-9);
    let mut head = Table::new(&[
        "kernel",
        "init-diff/s",
        "epoch-diff/s",
        "reconverge",
    ]);
    for (name, s) in [("local-block", &local), ("global-walk", &global)] {
        head.row(&[
            name.into(),
            format!("{:.2e}", s.init_diffusions_per_sec()),
            format!("{:.2e}", s.epoch_diffusions_per_sec()),
            fmt_secs(s.reconverge_mean()),
        ]);
    }
    print!("{}", head.render());
    println!("\nlocal-block vs global-walk: {speedup:.2}x diffusions/sec on the cold solve");

    // ---- part 3: machine-readable artifact ------------------------------
    let json = Json::new()
        .int_field("schema", 1)
        .str_field("bench", "streaming_churn")
        .bool_field("measured", true)
        .str_field("environment", &bench_env)
        .int_field("n", n as u64)
        .int_field("k", K as u64)
        .num_field("tol", TOL)
        .num_field("steady_updates_per_sec", steady_upd_per_sec)
        .arr_num_field("warm_reconverge_secs_by_batch", &warm_reconverge_secs)
        .arr_num_field("cold_vs_warm_update_saving_by_batch", &upd_savings)
        .obj_field("local", local.to_json())
        .obj_field("global", global.to_json())
        .num_field("local_vs_global_speedup", speedup);
    let path = bench_json_dir().join("BENCH_stream.json");
    json.write(&path).expect("write BENCH_stream.json");
    println!("wrote {}", path.display());

    if let Some(min) = std::env::var("DITER_BENCH_ASSERT_SPEEDUP")
        .ok()
        .and_then(|s| s.parse::<f64>().ok())
    {
        assert!(
            speedup >= min,
            "local-block kernel must be ≥{min:.2}x the global walk \
             (measured {speedup:.2}x)"
        );
    }
}
