//! Bench: the streaming engine under sustained churn — steady-state
//! updates/sec and time-to-reconverge per mutation batch, against a full
//! V2 restart on every batch (the baseline an offline system pays).
//!
//! Expected shape: warm rebases cost a small fraction of a cold solve for
//! small batches (the §3.2 claim at scale), and the gap narrows as the
//! batch size grows towards rewriting the whole graph.

use std::time::Duration;

use diter::bench_harness::{bench_header, fmt_secs, Table};
use diter::coordinator::{v2, DistributedConfig, StreamingEngine};
use diter::graph::{power_law_web_graph, ChurnModel, MutableDigraph, MutationStream};
use diter::partition::Partition;
use diter::solver::SequenceKind;

fn main() {
    bench_header(
        "streaming_churn",
        "warm rebase vs cold restart under churn (web graph, V2, K=4)",
    );
    let n = std::env::var("DITER_BENCH_N")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(10_000usize);
    let k = 4usize;
    let tol = 1e-9;
    let batches_per_size = 3usize;

    let g = power_law_web_graph(n, 8, 0.1, 7);
    println!("graph: {} nodes, {} edges; tol {tol:.0e}\n", g.n(), g.m());
    let mg = MutableDigraph::from_digraph(&g, n);
    let mut cfg = DistributedConfig::new(Partition::contiguous(n, k).unwrap())
        .with_tol(tol)
        .with_seed(5)
        .with_sequence(SequenceKind::GreedyMaxFluid);
    cfg.max_wall = Duration::from_secs(300);
    let cold_cfg = cfg.clone();

    let mut engine = StreamingEngine::new(mg, 0.85, true, cfg).expect("engine");
    let init = engine.converge().expect("initial solve");
    assert!(init.solution.converged, "initial solve must converge");
    println!(
        "initial solve: {} updates, {} ({:.2e} upd/s)\n",
        init.solution.total_updates,
        fmt_secs(init.solution.wall_secs),
        init.solution.total_updates as f64 / init.solution.wall_secs.max(1e-9)
    );

    let mut table = Table::new(&[
        "batch-size",
        "model",
        "reconverge",
        "warm-upd",
        "cold-wall",
        "cold-upd",
        "upd-saving",
        "steady-upd/s",
    ]);
    let mut stream = MutationStream::new(ChurnModel::RandomRewire, 31);
    let mut burst = MutationStream::new(ChurnModel::HotSpotBurst { burst: 64 }, 37);

    for &batch_size in &[16usize, 64, 256, 1024] {
        let mut warm_wall = 0.0f64;
        let mut warm_upd = 0u64;
        let mut cold_wall = 0.0f64;
        let mut cold_upd = 0u64;
        for b in 0..batches_per_size {
            let batch = if b == batches_per_size - 1 {
                burst.next_batch(engine.graph(), batch_size)
            } else {
                stream.next_batch(engine.graph(), batch_size)
            };
            let report = engine.apply_batch(&batch).expect("apply");
            assert!(
                report.solution.converged,
                "batch size {batch_size}: residual {:.3e}",
                report.solution.residual
            );
            warm_wall += report.solution.wall_secs;
            warm_upd += report.solution.total_updates;
            let cold = v2::solve_v2(engine.problem(), &cold_cfg).expect("cold");
            assert!(cold.converged);
            cold_wall += cold.wall_secs;
            cold_upd += cold.total_updates;
        }
        let inv = 1.0 / batches_per_size as f64;
        table.row(&[
            batch_size.to_string(),
            "rewire+burst".into(),
            fmt_secs(warm_wall * inv),
            (warm_upd / batches_per_size as u64).to_string(),
            fmt_secs(cold_wall * inv),
            (cold_upd / batches_per_size as u64).to_string(),
            format!("{:.1}x", cold_upd as f64 / warm_upd.max(1) as f64),
            format!("{:.2e}", engine.steady_updates_per_sec()),
        ]);
    }
    print!("{}", table.render());

    let summary = engine.finish().expect("finish");
    println!(
        "\n{} epochs, {} mutations; whole-run mean {:.2e} upd/s; final residual {:.2e}",
        summary.epochs,
        summary.mutations_applied,
        summary.steady_updates_per_sec,
        summary.final_solution.residual
    );
    println!("(reconverge = mean wall-clock from batch application to total fluid < tol)");
}
