//! Bench: the streaming engine under sustained churn — steady-state
//! updates/sec and time-to-reconverge per mutation batch, against a full
//! V2 restart on every batch (the baseline an offline system pays) — plus
//! the **kernel head-to-head**: the same churn workload driven through the
//! partition-local block kernel and through the pre-refactor global-walk
//! kernel, in the same binary, recording the diffusions/sec ratio — plus
//! the **epoch-protocol head-to-head**: the same churn driven through the
//! gather (leader rebase) and local (V1 halo rebase) epoch protocols,
//! recording the per-batch epoch-transition latency each pays.
//!
//! Emits `BENCH_stream.json` (machine-readable: updates/sec,
//! time-to-reconverge, diffusions/sec per kernel, the local/global kernel
//! speedup, and the local/gather transition speedup) into
//! `DITER_BENCH_JSON_DIR` (default `.`). The committed copy at the repo
//! root is the perf-trajectory baseline the CI gate
//! (`tools/bench_gate.py`) compares against.
//!
//! Env knobs: `DITER_BENCH_N` (graph size), `DITER_BENCH_JSON_DIR`
//! (relative paths resolve against the workspace root, not cargo's
//! package-root cwd), `DITER_BENCH_ENV` (recorded as the measurement
//! environment), `DITER_BENCH_ASSERT_SPEEDUP` (fail unless local ≥ this
//! × global).

use std::time::Duration;

use diter::bench_harness::{bench_header, bench_json_dir, fmt_secs, Json, Table};
use diter::coordinator::{v2, DistributedConfig, KernelKind, RebaseMode, StreamingEngine};
use diter::graph::{power_law_web_graph, ChurnModel, MutableDigraph, MutationStream};
use diter::linalg::vec_ops::dist1;
use diter::partition::Partition;
use diter::solver::SequenceKind;

const K: usize = 4;
const TOL: f64 = 1e-9;

fn base_cfg(n: usize, kernel: KernelKind) -> DistributedConfig {
    let mut cfg = DistributedConfig::new(Partition::contiguous(n, K).unwrap())
        .with_tol(TOL)
        .with_seed(5)
        .with_sequence(SequenceKind::GreedyMaxFluid)
        .with_kernel(kernel);
    cfg.max_wall = Duration::from_secs(300);
    cfg
}

/// One kernel's run over the shared churn workload.
struct KernelStats {
    init_updates: u64,
    init_wall: f64,
    reconverge_walls: Vec<f64>,
    epoch_updates: u64,
    epoch_wall: f64,
}

impl KernelStats {
    /// Diffusions/sec over the initial cold solve — the headline kernel
    /// throughput (scalar diffusions == scalar updates in this scheme).
    fn init_diffusions_per_sec(&self) -> f64 {
        self.init_updates as f64 / self.init_wall.max(1e-9)
    }

    fn epoch_diffusions_per_sec(&self) -> f64 {
        self.epoch_updates as f64 / self.epoch_wall.max(1e-9)
    }

    fn reconverge_mean(&self) -> f64 {
        if self.reconverge_walls.is_empty() {
            return 0.0;
        }
        self.reconverge_walls.iter().sum::<f64>() / self.reconverge_walls.len() as f64
    }

    fn to_json(&self) -> Json {
        Json::new()
            .num_field("init_diffusions_per_sec", self.init_diffusions_per_sec())
            .num_field("epoch_diffusions_per_sec", self.epoch_diffusions_per_sec())
            .int_field("init_updates", self.init_updates)
            .num_field("init_wall_secs", self.init_wall)
            .num_field("reconverge_secs_mean", self.reconverge_mean())
            .arr_num_field("reconverge_secs", &self.reconverge_walls)
    }
}

/// Drive one engine (one kernel) through the head-to-head workload: cold
/// solve + `batches` rewire batches of `batch_size`. Streams are re-seeded
/// identically per kernel, and batches are generated against each engine's
/// own evolving graph — the graphs evolve identically, so both kernels see
/// the same mutation sequence.
fn run_kernel(n: usize, kernel: KernelKind, batches: usize, batch_size: usize) -> KernelStats {
    let g = power_law_web_graph(n, 8, 0.1, 7);
    let mg = MutableDigraph::from_digraph(&g, n);
    let mut engine = StreamingEngine::new(mg, 0.85, true, base_cfg(n, kernel)).expect("engine");
    let init = engine.converge().expect("initial solve");
    assert!(
        init.solution.converged,
        "[{}] initial solve must converge (residual {:.3e})",
        kernel.name(),
        init.solution.residual
    );
    let mut stream = MutationStream::new(ChurnModel::RandomRewire, 131);
    let mut walls = Vec::with_capacity(batches);
    let mut epoch_updates = 0u64;
    let mut epoch_wall = 0.0f64;
    for _ in 0..batches {
        let batch = stream.next_batch(engine.graph(), batch_size);
        let report = engine.apply_batch(&batch).expect("apply");
        assert!(
            report.solution.converged,
            "[{}] reconverge failed (residual {:.3e})",
            kernel.name(),
            report.solution.residual
        );
        walls.push(report.solution.wall_secs);
        epoch_updates += report.solution.total_updates;
        epoch_wall += report.solution.wall_secs;
    }
    engine.finish().expect("finish");
    KernelStats {
        init_updates: init.solution.total_updates,
        init_wall: init.solution.wall_secs,
        reconverge_walls: walls,
        epoch_updates,
        epoch_wall,
    }
}

/// One epoch protocol's run over the shared churn workload: the per-batch
/// transition latency (the quantity the protocols trade — the
/// reconvergence after it is common to both) and the final solution for
/// the cross-protocol agreement check.
struct RebaseStats {
    transition_secs: Vec<f64>,
    reconverge_secs: Vec<f64>,
    final_x: Vec<f64>,
}

fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Drive one engine (one epoch protocol) through the head-to-head
/// workload — same seeds per protocol, so both see identical mutation
/// sequences over identically-evolving graphs.
fn run_rebase_mode(n: usize, mode: RebaseMode, batches: usize, batch_size: usize) -> RebaseStats {
    let g = power_law_web_graph(n, 8, 0.1, 7);
    let mg = MutableDigraph::from_digraph(&g, n);
    let cfg = base_cfg(n, KernelKind::LocalBlock).with_rebase(mode);
    let mut engine = StreamingEngine::new(mg, 0.85, true, cfg).expect("engine");
    let init = engine.converge().expect("initial solve");
    assert!(
        init.solution.converged,
        "[{}] initial solve must converge (residual {:.3e})",
        mode.name(),
        init.solution.residual
    );
    let mut stream = MutationStream::new(ChurnModel::RandomRewire, 977);
    let mut transition_secs = Vec::with_capacity(batches);
    let mut reconverge_secs = Vec::with_capacity(batches);
    for _ in 0..batches {
        let batch = stream.next_batch(engine.graph(), batch_size);
        let report = engine.apply_batch(&batch).expect("apply");
        assert!(
            report.solution.converged,
            "[{}] reconverge failed (residual {:.3e})",
            mode.name(),
            report.solution.residual
        );
        transition_secs.push(engine.last_rebase_secs());
        reconverge_secs.push(report.solution.wall_secs);
    }
    let final_x = engine.solution().expect("solution");
    engine.finish().expect("finish");
    RebaseStats {
        transition_secs,
        reconverge_secs,
        final_x,
    }
}

fn main() {
    bench_header(
        "streaming_churn",
        "warm rebase vs cold restart under churn (web graph, V2, K=4) \
         + local-block vs global-walk kernel head-to-head",
    );
    let n = std::env::var("DITER_BENCH_N")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(10_000usize);
    let bench_env = std::env::var("DITER_BENCH_ENV").unwrap_or_else(|_| "local".into());
    let batches_per_size = 3usize;

    // ---- part 1: warm rebase vs cold restart (local kernel) -------------
    let g = power_law_web_graph(n, 8, 0.1, 7);
    println!("graph: {} nodes, {} edges; tol {TOL:.0e}\n", g.n(), g.m());
    let mg = MutableDigraph::from_digraph(&g, n);
    let cfg = base_cfg(n, KernelKind::LocalBlock);
    let cold_cfg = cfg.clone();

    let mut engine = StreamingEngine::new(mg, 0.85, true, cfg).expect("engine");
    let init = engine.converge().expect("initial solve");
    assert!(init.solution.converged, "initial solve must converge");
    println!(
        "initial solve: {} updates, {} ({:.2e} upd/s)\n",
        init.solution.total_updates,
        fmt_secs(init.solution.wall_secs),
        init.solution.total_updates as f64 / init.solution.wall_secs.max(1e-9)
    );

    let mut table = Table::new(&[
        "batch-size",
        "model",
        "reconverge",
        "warm-upd",
        "cold-wall",
        "cold-upd",
        "upd-saving",
        "steady-upd/s",
    ]);
    let mut stream = MutationStream::new(ChurnModel::RandomRewire, 31);
    let mut burst = MutationStream::new(ChurnModel::HotSpotBurst { burst: 64 }, 37);

    let mut warm_reconverge_secs = Vec::new();
    let mut upd_savings = Vec::new();
    for &batch_size in &[16usize, 64, 256, 1024] {
        let mut warm_wall = 0.0f64;
        let mut warm_upd = 0u64;
        let mut cold_wall = 0.0f64;
        let mut cold_upd = 0u64;
        for b in 0..batches_per_size {
            let batch = if b == batches_per_size - 1 {
                burst.next_batch(engine.graph(), batch_size)
            } else {
                stream.next_batch(engine.graph(), batch_size)
            };
            let report = engine.apply_batch(&batch).expect("apply");
            assert!(
                report.solution.converged,
                "batch size {batch_size}: residual {:.3e}",
                report.solution.residual
            );
            warm_wall += report.solution.wall_secs;
            warm_upd += report.solution.total_updates;
            let cold = v2::solve_v2(engine.problem(), &cold_cfg).expect("cold");
            assert!(cold.converged);
            cold_wall += cold.wall_secs;
            cold_upd += cold.total_updates;
        }
        let inv = 1.0 / batches_per_size as f64;
        warm_reconverge_secs.push(warm_wall * inv);
        upd_savings.push(cold_upd as f64 / warm_upd.max(1) as f64);
        table.row(&[
            batch_size.to_string(),
            "rewire+burst".into(),
            fmt_secs(warm_wall * inv),
            (warm_upd / batches_per_size as u64).to_string(),
            fmt_secs(cold_wall * inv),
            (cold_upd / batches_per_size as u64).to_string(),
            format!("{:.1}x", cold_upd as f64 / warm_upd.max(1) as f64),
            format!("{:.2e}", engine.steady_updates_per_sec()),
        ]);
    }
    print!("{}", table.render());

    let steady_upd_per_sec = engine.steady_updates_per_sec();
    let summary = engine.finish().expect("finish");
    println!(
        "\n{} epochs, {} mutations; whole-run mean {:.2e} upd/s; final residual {:.2e}",
        summary.epochs,
        summary.mutations_applied,
        summary.steady_updates_per_sec,
        summary.final_solution.residual
    );
    println!("(reconverge = mean wall-clock from batch application to total fluid < tol)\n");

    // ---- part 2: kernel head-to-head ------------------------------------
    println!("kernel head-to-head (same workload, same binary):");
    let local = run_kernel(n, KernelKind::LocalBlock, 4, 64);
    let global = run_kernel(n, KernelKind::GlobalWalk, 4, 64);
    let speedup = local.init_diffusions_per_sec() / global.init_diffusions_per_sec().max(1e-9);
    let mut head = Table::new(&[
        "kernel",
        "init-diff/s",
        "epoch-diff/s",
        "reconverge",
    ]);
    for (name, s) in [("local-block", &local), ("global-walk", &global)] {
        head.row(&[
            name.into(),
            format!("{:.2e}", s.init_diffusions_per_sec()),
            format!("{:.2e}", s.epoch_diffusions_per_sec()),
            fmt_secs(s.reconverge_mean()),
        ]);
    }
    print!("{}", head.render());
    println!("\nlocal-block vs global-walk: {speedup:.2}x diffusions/sec on the cold solve");

    // ---- part 3: epoch-protocol head-to-head ----------------------------
    println!("\nepoch-protocol head-to-head (same churn, same binary):");
    let gather = run_rebase_mode(n, RebaseMode::Gather, 6, 128);
    let local_rb = run_rebase_mode(n, RebaseMode::Local, 6, 128);
    let agreement = dist1(&gather.final_x, &local_rb.final_x);
    assert!(agreement < 1e-6, "protocols disagree on the fixed point: Δ₁ = {agreement:.3e}");
    let rebase_speedup = mean(&gather.transition_secs) / mean(&local_rb.transition_secs).max(1e-9);
    let mut proto = Table::new(&["protocol", "transition", "reconverge"]);
    for (name, s) in [("gather", &gather), ("local", &local_rb)] {
        proto.row(&[
            name.into(),
            fmt_secs(mean(&s.transition_secs)),
            fmt_secs(mean(&s.reconverge_secs)),
        ]);
    }
    print!("{}", proto.render());
    println!(
        "\nlocal vs gather epoch transition: {rebase_speedup:.2}x \
         (fixed points agree, Δ₁ = {agreement:.1e})"
    );

    // ---- part 4: machine-readable artifact ------------------------------
    let json = Json::new()
        .int_field("schema", 1)
        .str_field("bench", "streaming_churn")
        .bool_field("measured", true)
        .str_field("environment", &bench_env)
        .int_field("n", n as u64)
        .int_field("k", K as u64)
        .num_field("tol", TOL)
        .num_field("steady_updates_per_sec", steady_upd_per_sec)
        .arr_num_field("warm_reconverge_secs_by_batch", &warm_reconverge_secs)
        .arr_num_field("cold_vs_warm_update_saving_by_batch", &upd_savings)
        .obj_field("local", local.to_json())
        .obj_field("global", global.to_json())
        .num_field("local_vs_global_speedup", speedup)
        .obj_field(
            "rebase_gather",
            Json::new()
                .num_field("transition_secs_mean", mean(&gather.transition_secs))
                .arr_num_field("transition_secs", &gather.transition_secs)
                .num_field("reconverge_secs_mean", mean(&gather.reconverge_secs)),
        )
        .obj_field(
            "rebase_local",
            Json::new()
                .num_field("transition_secs_mean", mean(&local_rb.transition_secs))
                .arr_num_field("transition_secs", &local_rb.transition_secs)
                .num_field("reconverge_secs_mean", mean(&local_rb.reconverge_secs)),
        )
        .num_field("rebase_local_vs_gather_speedup", rebase_speedup);
    let path = bench_json_dir().join("BENCH_stream.json");
    json.write(&path).expect("write BENCH_stream.json");
    println!("wrote {}", path.display());

    if let Some(min) = std::env::var("DITER_BENCH_ASSERT_SPEEDUP")
        .ok()
        .and_then(|s| s.parse::<f64>().ok())
    {
        assert!(
            speedup >= min,
            "local-block kernel must be ≥{min:.2}x the global walk \
             (measured {speedup:.2}x)"
        );
    }
}
