"""AOT compile path: lower every Layer-2 program to HLO **text** artifacts.

Interchange format is HLO text, NOT ``lowered.compile().serialize()`` and
NOT a serialized ``HloModuleProto``: jax >= 0.5 emits protos with 64-bit
instruction ids which xla_extension 0.5.1 (what the published ``xla`` 0.1.6
crate binds) rejects (``proto.id() <= INT_MAX``). The text parser on the
rust side (``HloModuleProto::from_text_file``) reassigns ids and
round-trips cleanly. See /opt/xla-example/README.md.

Outputs (under ``artifacts/``):
  * ``{program}_{suffix}.hlo.txt``  — one HLO module per (program, shape)
  * ``manifest.txt``                — machine-readable index for the rust
                                      runtime: name, kind, dims, arg spec,
                                      file name (format documented below)

Usage: ``python -m compile.aot --out-dir ../artifacts`` (from ``python/``).
Re-running is cheap and idempotent; the Makefile keys off the manifest.
"""

from __future__ import annotations

import argparse
import os
import sys

import jax

jax.config.update("jax_enable_x64", True)

from jax._src.lib import xla_client as xc

from compile import model

MANIFEST_NAME = "manifest.txt"
MANIFEST_HEADER = (
    "# diter AOT manifest v1\n"
    "# name kind dims(comma) file\n"
    "# arg spec is fixed per kind — see rust/src/runtime/manifest.rs\n"
)


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the 0.5.1-safe path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_program(fn, spec):
    return jax.jit(fn).lower(*spec)


def build_all(out_dir: str, only: str | None = None, verbose: bool = True):
    os.makedirs(out_dir, exist_ok=True)
    entries = []
    for name, (fn, spec_builder, grid) in model.PROGRAMS.items():
        if only is not None and name != only:
            continue
        for dims in grid:
            spec = spec_builder(*dims)
            suffix = "x".join(str(d) for d in dims)
            fname = f"{name}_{suffix}.hlo.txt"
            text = to_hlo_text(lower_program(fn, spec))
            with open(os.path.join(out_dir, fname), "w") as f:
                f.write(text)
            entries.append((name, dims, fname))
            if verbose:
                print(f"  lowered {name}{dims} -> {fname} ({len(text)} chars)")
    manifest = os.path.join(out_dir, MANIFEST_NAME)
    with open(manifest, "w") as f:
        f.write(MANIFEST_HEADER)
        for name, dims, fname in entries:
            dimstr = ",".join(str(d) for d in dims)
            f.write(f"{name} {name} {dimstr} {fname}\n")
    if verbose:
        print(f"wrote {len(entries)} artifacts + {manifest}")
    return entries


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None, help="lower a single program")
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args(argv)
    build_all(args.out_dir, only=args.only, verbose=not args.quiet)
    return 0


if __name__ == "__main__":
    sys.exit(main())
