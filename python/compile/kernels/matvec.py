"""Layer-1 Pallas kernels: residual / fluid evaluation and tiled matvec.

The *remaining fluid* of partition k (paper §4.1) is

    r_k = sum_{i in Omega_k} | L_i(P).H + B_i - H_i |

and its elementwise version ``F_i = L_i(P).H + B_i - H_i`` is exactly the
fluid vector F of eq. (4): ``F = F0 + P.H - H``. Computing F (and its L1
norm) is the second hot spot of a PID: it drives the share trigger
``r_k < T_k`` and the §4.4 distance-to-limit bound.

The matvec is tiled over the row dimension so each grid step works on an
MXU/VPU-friendly ``(bm, n)`` tile; on real TPU ``bm`` would be a multiple of
8 (f32 sublane) — here interpret=True, so the tiling expresses the schedule
without Mosaic lowering.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["fluid", "fluid_kernel", "matvec", "matvec_kernel", "residual_norm"]


def fluid_kernel(p_ref, h_ref, b_ref, hsel_ref, o_ref):
    """Elementwise fluid ``F = P_rows . H + B - H_sel`` for one row tile."""
    o_ref[...] = p_ref[...] @ h_ref[...] + b_ref[...] - hsel_ref[...]


@jax.jit
def fluid(p_rows, h, b, h_sel):
    """Fluid vector of a block: ``F_block = P_rows @ H + B - H[idx]``.

    Args:
      p_rows: ``(m, n)`` rows ``L_i(P)``.
      h:      ``(n,)`` history vector.
      b:      ``(m,)`` block's B coordinates.
      h_sel:  ``(m,)`` the H coordinates of the block (``H[idx]``), selected
              by the caller so the kernel stays gather-free.

    Returns:
      ``(m,)`` fluid per block row; ``sum(|.|)`` is the paper's ``r_k``.
    """
    m, _ = p_rows.shape
    bm = _row_tile(m)
    grid = (m // bm,)
    return pl.pallas_call(
        fluid_kernel,
        out_shape=jax.ShapeDtypeStruct((m,), h.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, p_rows.shape[1]), lambda r: (r, 0)),
            pl.BlockSpec((p_rows.shape[1],), lambda r: (0,)),
            pl.BlockSpec((bm,), lambda r: (r,)),
            pl.BlockSpec((bm,), lambda r: (r,)),
        ],
        out_specs=pl.BlockSpec((bm,), lambda r: (r,)),
        interpret=True,
    )(p_rows, h, b, h_sel)


def matvec_kernel(p_ref, x_ref, o_ref):
    """One row-tile of a dense matvec ``o = P_tile @ x``."""
    o_ref[...] = p_ref[...] @ x_ref[...]


@jax.jit
def matvec(p, x):
    """Tiled dense matvec ``P @ x`` with a row-blocked schedule."""
    m, n = p.shape
    bm = _row_tile(m)
    grid = (m // bm,)
    return pl.pallas_call(
        matvec_kernel,
        out_shape=jax.ShapeDtypeStruct((m,), x.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, n), lambda r: (r, 0)),
            pl.BlockSpec((n,), lambda r: (0,)),
        ],
        out_specs=pl.BlockSpec((bm,), lambda r: (r,)),
        interpret=True,
    )(p, x)


@jax.jit
def residual_norm(p, h, b):
    """Global remaining fluid ``sum_i |L_i(P).H + B_i - H_i|`` (square P)."""
    f = matvec(p, h) + b - h
    return jnp.sum(jnp.abs(f))


def _row_tile(m: int) -> int:
    """Largest power-of-two row tile <= 128 that divides m (>=1)."""
    bm = 1
    t = 1
    while t * 2 <= 128 and m % (t * 2) == 0 and t * 2 <= m:
        t *= 2
        bm = t
    return max(bm, 1)
