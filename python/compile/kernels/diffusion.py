"""Layer-1 Pallas kernels for the D-iteration local diffusion sweep.

The D-iteration (Hong, 2012) updates the history vector H one coordinate at a
time (eq. 5 of the paper):

    H_i  <-  L_i(P) . H + B_i

Within a partition ``Omega_k`` the updates are *sequential* (each update reads
the H produced by the previous one — the Gauss-Seidel-like data dependence
that gives the D-iteration its edge over Jacobi), while partitions run in
parallel. The kernel below is therefore the per-PID hot loop: one *sweep*
over a block of ``m`` rows of P against the full (or locally known) H of
size ``n``.

TPU adaptation (DESIGN.md §Hardware-Adaptation): the block's rows of P are
held as a single VMEM-resident tile (``m x n`` fits comfortably: even
128 x 1024 f64 is 1 MiB << 16 MiB VMEM); the in-block recurrence is an
on-chip ``fori_loop``; the dot product per row vectorizes over the VPU lanes.
``interpret=True`` everywhere — the CPU PJRT plugin cannot execute Mosaic
custom-calls; real-TPU numbers are estimated analytically in DESIGN.md.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["d_sweep", "d_sweep_kernel", "d_multi_sweep"]


def d_sweep_kernel(p_ref, idx_ref, h_ref, b_ref, o_ref):
    """Sequentially apply ``H[idx[t]] = P_rows[t] . H + B[t]`` for t in 0..m.

    Refs:
      p_ref:   (m, n)  block of rows ``L_i(P)`` for i in the partition.
      idx_ref: (m,)    int32 global coordinate of each row (the ``i``).
      h_ref:   (n,)    input history vector H (full view, V1 scheme).
      b_ref:   (m,)    the coordinates ``B_i`` matching ``idx``.
      o_ref:   (n,)    output H after the sweep.

    The loop *must* read ``o_ref`` each iteration: row t sees the updates of
    rows < t. That sequential dependence is the algorithm, not an accident.
    """
    o_ref[...] = h_ref[...]
    m = p_ref.shape[0]

    def body(t, carry):
        row = p_ref[t, :]
        h = o_ref[...]
        val = jnp.dot(row, h) + b_ref[t]
        i = idx_ref[t]
        o_ref[i] = val
        return carry

    jax.lax.fori_loop(0, m, body, 0)


@functools.partial(jax.jit, static_argnames=())
def d_sweep(p_rows, idx, h, b):
    """One local D-iteration sweep over a dense block of rows.

    Args:
      p_rows: ``(m, n)`` float — rows ``L_i(P)`` of the iteration matrix.
      idx:    ``(m,)`` int32 — global indices ``i`` of those rows.
      h:      ``(n,)`` float — current history vector H.
      b:      ``(m,)`` float — ``B_i`` for the block's rows.

    Returns:
      ``(n,)`` float — H after the sequential sweep.
    """
    return pl.pallas_call(
        d_sweep_kernel,
        out_shape=jax.ShapeDtypeStruct(h.shape, h.dtype),
        interpret=True,
    )(p_rows, idx, h, b)


def d_multi_sweep(p_rows, idx, h, b, n_sweeps: int):
    """Apply ``d_sweep`` ``n_sweeps`` times (a PID's work between shares).

    The paper's Fig. 1 protocol runs each PID's cyclic sequence "exactly
    twice before sharing" — that is ``n_sweeps=2``. Lowered as a single XLA
    while-loop so the AOT artifact is one fused program.
    """

    def body(_, h_cur):
        return d_sweep(p_rows, idx, h_cur, b)

    return jax.lax.fori_loop(0, n_sweeps, body, h)
