"""Pure-numpy oracles for every Layer-1 kernel and Layer-2 graph.

These are the CORE correctness signal of the build path: pytest compares
each Pallas kernel and each lowered model function against the functions
here (``assert_allclose``), and the rust test-suite embeds goldens computed
from the same formulas. Nothing in this file uses Pallas.
"""

from __future__ import annotations

import numpy as np

__all__ = [
    "d_sweep_ref",
    "d_multi_sweep_ref",
    "fluid_ref",
    "matvec_ref",
    "residual_norm_ref",
    "jacobi_step_ref",
    "power_step_ref",
    "pagerank_step_ref",
    "d_iteration_ref",
    "to_iteration_matrix",
]


def d_sweep_ref(p_rows, idx, h, b):
    """Sequential D-iteration sweep (eq. 5 applied for each row in order)."""
    h = np.array(h, dtype=np.float64, copy=True)
    p_rows = np.asarray(p_rows, dtype=np.float64)
    b = np.asarray(b, dtype=np.float64)
    idx = np.asarray(idx)
    for t in range(p_rows.shape[0]):
        h[int(idx[t])] = float(p_rows[t] @ h) + float(b[t])
    return h


def d_multi_sweep_ref(p_rows, idx, h, b, n_sweeps):
    for _ in range(n_sweeps):
        h = d_sweep_ref(p_rows, idx, h, b)
    return h


def fluid_ref(p_rows, h, b, h_sel):
    """Elementwise fluid ``F = P_rows @ H + B - H_sel``."""
    return np.asarray(p_rows) @ np.asarray(h) + np.asarray(b) - np.asarray(h_sel)


def matvec_ref(p, x):
    return np.asarray(p) @ np.asarray(x)


def residual_norm_ref(p, h, b):
    """Global remaining fluid ``sum_i |L_i(P).H + B_i - H_i|`` (paper §4.1)."""
    p, h, b = map(np.asarray, (p, h, b))
    return float(np.sum(np.abs(p @ h + b - h)))


def jacobi_step_ref(p, h, b):
    """One synchronous Jacobi step ``H' = P.H + B``."""
    return np.asarray(p) @ np.asarray(h) + np.asarray(b)


def power_step_ref(p, x):
    """One L1-normalized power-iteration step."""
    y = np.asarray(p) @ np.asarray(x)
    n = np.sum(np.abs(y))
    return y / (n if n != 0.0 else 1.0)


def pagerank_step_ref(s, x, d, teleport):
    """Dense PageRank step ``x' = d.S.x + (1-d+d.dangling(x)) . teleport``.

    ``s`` is the column-stochastic link matrix with all-zero columns for
    dangling pages; the lost mass ``d * (1 - 1.S.x)`` is re-injected through
    the teleport vector together with the usual ``(1-d)`` term.
    """
    s, x, teleport = map(np.asarray, (s, x, teleport))
    sx = s @ x
    lost = 1.0 - float(np.sum(sx))  # mass swallowed by dangling columns
    return d * sx + (1.0 - d + d * lost) * teleport


def d_iteration_ref(p, b, sequence, h0=None):
    """Full sequential D-iteration via eq. (5); returns (H, trace of H).

    ``sequence`` is the diffusion order I = {i_1, i_2, ...}. Starting point
    follows paper §2.1.1: ``H_0 = B`` is free, so ``h0`` defaults to B.
    """
    p = np.asarray(p, dtype=np.float64)
    b = np.asarray(b, dtype=np.float64)
    h = np.array(b if h0 is None else h0, dtype=np.float64, copy=True)
    trace = []
    for i in sequence:
        h[i] = float(p[i] @ h) + float(b[i])
        trace.append(h.copy())
    return h, trace


def to_iteration_matrix(a, rhs):
    """Turn ``A.X = B`` into ``X = P.X + B'``: ``p_ij = -a_ij/a_ii`` (i != j),
    ``p_ii = 0``, ``b'_i = rhs_i / a_ii`` — the construction of paper §5."""
    a = np.asarray(a, dtype=np.float64)
    rhs = np.asarray(rhs, dtype=np.float64)
    d = np.diag(a)
    p = -a / d[:, None]
    np.fill_diagonal(p, 0.0)
    return p, rhs / d
