"""Layer-2 JAX compute graphs for the D-iteration stack.

Each function here is a *whole program* a PID executes between communication
events; they call the Layer-1 Pallas kernels (``kernels.diffusion``,
``kernels.matvec``) so that kernel + surrounding graph lower into ONE HLO
module per artifact. ``aot.py`` lowers every entry of :data:`PROGRAMS` at a
set of shapes and writes HLO text + a manifest for the rust runtime.

All programs use f64 (``jax_enable_x64``) so numerics match the rust
coordinator bit-for-bit up to reassociation.

Conventions shared with ``rust/src/runtime``:
  * every program returns a TUPLE (lowered with ``return_tuple=True``) —
    the rust side unwraps with ``to_tuple1``/``to_tuple``;
  * argument order is exactly the order documented per function.
"""

from __future__ import annotations

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp

from compile.kernels import diffusion, matvec

__all__ = [
    "d_sweep_program",
    "d_round_program",
    "fluid_norm_program",
    "jacobi_step_program",
    "power_step_program",
    "pagerank_step_program",
    "PROGRAMS",
]


def d_sweep_program(p_rows, idx, h, b):
    """One local D-iteration sweep. Args: p_rows(m,n) f64, idx(m) i32,
    h(n) f64, b(m) f64 -> (h'(n) f64,)."""
    return (diffusion.d_sweep(p_rows, idx, h, b),)


def d_round_program(p_rows, idx, h, b):
    """A PID's full work quantum between shares: TWO sequential sweeps
    (the Fig.1 protocol: cyclic sequence applied exactly twice before
    sharing) followed by the block fluid for the r_k<T_k trigger.

    Args: p_rows(m,n) f64, idx(m) i32, h(n) f64, b(m) f64
    Returns: (h'(n) f64, fluid(m) f64, r_k scalar f64).
    """
    h2 = diffusion.d_multi_sweep(p_rows, idx, h, b, 2)
    h_sel = h2[idx]
    f = matvec.fluid(p_rows, h2, b, h_sel)
    return (h2, f, jnp.sum(jnp.abs(f)))


def fluid_norm_program(p, h, b):
    """Global remaining fluid sum_i |L_i(P).H+B_i-H_i|.
    Args: p(n,n) f64, h(n) f64, b(n) f64 -> (r scalar f64,)."""
    return (matvec.residual_norm(p, h, b),)


def jacobi_step_program(p, h, b):
    """One synchronous Jacobi step H' = P.H + B (baseline).
    Args: p(n,n), h(n), b(n) -> (h'(n),)."""
    return (matvec.matvec(p, h) + b,)


def power_step_program(p, x):
    """One L1-normalized power-iteration step (eigenvector baseline).
    Args: p(n,n), x(n) -> (x'(n),)."""
    y = matvec.matvec(p, x)
    n = jnp.sum(jnp.abs(y))
    return (y / jnp.where(n == 0.0, 1.0, n),)


def pagerank_step_program(s, x, teleport, d):
    """Dense PageRank step with dangling-mass re-injection.
    Args: s(n,n) col-stochastic, x(n), teleport(n), d scalar -> (x'(n),)."""
    sx = matvec.matvec(s, x)
    lost = 1.0 - jnp.sum(sx)
    return (d * sx + (1.0 - d + d * lost) * teleport,)


def _f64(*dims):
    return jax.ShapeDtypeStruct(dims, jnp.float64)


def _i32(*dims):
    return jax.ShapeDtypeStruct(dims, jnp.int32)


def _sweep_spec(m, n):
    return (_f64(m, n), _i32(m), _f64(n), _f64(m))


def _square_spec(n):
    return (_f64(n, n), _f64(n), _f64(n))


#: name -> (callable, shape-spec builder, parameter grid)
#: The grid entries become one artifact each: ``{name}_{suffix}.hlo.txt``.
PROGRAMS = {
    "d_sweep": (
        d_sweep_program,
        _sweep_spec,
        [(2, 4), (4, 4), (32, 128), (64, 256), (128, 512)],
    ),
    "d_round": (
        d_round_program,
        _sweep_spec,
        [(2, 4), (32, 128), (64, 256)],
    ),
    "fluid_norm": (
        fluid_norm_program,
        _square_spec,
        [(4,), (128,), (256,)],
    ),
    "jacobi_step": (
        jacobi_step_program,
        _square_spec,
        [(4,), (256,)],
    ),
    "power_step": (
        power_step_program,
        lambda n: (_f64(n, n), _f64(n)),
        [(4,), (256,)],
    ),
    "pagerank_step": (
        pagerank_step_program,
        lambda n: (_f64(n, n), _f64(n), _f64(n), _f64()),
        [(256,)],
    ),
}
