"""AOT path: lowering to HLO text works, text is parseable-looking, and the
manifest round-trips. (The rust side re-verifies numerics end-to-end.)"""

import os

import numpy as np
import pytest

from compile import aot, model


def test_to_hlo_text_smallest_sweep():
    fn, spec_builder, _ = model.PROGRAMS["d_sweep"]
    lowered = aot.lower_program(fn, spec_builder(2, 4))
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "f64" in text
    # return_tuple=True => root is a tuple
    assert "tuple" in text


def test_to_hlo_text_has_while_loop_for_sweep():
    """The sequential in-block recurrence must lower to a while loop,
    not m unrolled dispatches (perf requirement, DESIGN.md §Perf L2)."""
    fn, spec_builder, _ = model.PROGRAMS["d_round"]
    text = aot.to_hlo_text(aot.lower_program(fn, spec_builder(32, 128)))
    assert "while" in text


def test_build_all_writes_manifest(tmp_path):
    out = str(tmp_path / "artifacts")
    entries = aot.build_all(out, only="jacobi_step", verbose=False)
    assert len(entries) == len(model.PROGRAMS["jacobi_step"][2])
    manifest = os.path.join(out, aot.MANIFEST_NAME)
    assert os.path.exists(manifest)
    lines = [
        l.split()
        for l in open(manifest)
        if l.strip() and not l.startswith("#")
    ]
    assert all(len(parts) == 4 for parts in lines)
    for name, kind, dims, fname in lines:
        assert name == "jacobi_step"
        assert os.path.exists(os.path.join(out, fname))
        assert all(d.isdigit() for d in dims.split(","))


def test_lowered_text_executes_in_jax():
    """Sanity: the jitted program (same lowering) computes the oracle."""
    from compile.kernels import ref

    a = np.array([[5.0, 3, 0, 0], [3, 7, 0, 0], [0, 0, 8, 4], [0, 0, 2, 3]])
    p, b = ref.to_iteration_matrix(a, np.ones(4))
    idx = np.arange(4, dtype=np.int32)
    (h,) = model.d_sweep_program(p, idx, b, b)
    np.testing.assert_allclose(
        np.asarray(h), ref.d_sweep_ref(p, idx, b, b), rtol=1e-12
    )
