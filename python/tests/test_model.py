"""Layer-2 correctness: every AOT-able program vs the numpy oracle, plus
golden values shared with the rust test-suite (rust/src/runtime tests embed
the same numbers — keep in sync)."""

import numpy as np
import pytest

from compile import model
from compile.kernels import ref


def a1_system():
    a = np.array([[5.0, 3, 0, 0], [3, 7, 0, 0], [0, 0, 8, 4], [0, 0, 2, 3]])
    return ref.to_iteration_matrix(a, np.ones(4))


def test_d_sweep_program_matches_ref():
    p, b = a1_system()
    idx = np.arange(4, dtype=np.int32)
    (got,) = model.d_sweep_program(p, idx, b, b)
    want = ref.d_sweep_ref(p, idx, b, b)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-12, atol=1e-12)


def test_d_round_program_is_two_sweeps_plus_fluid():
    rng = np.random.default_rng(1)
    m, n = 3, 6
    p = rng.uniform(-0.2, 0.2, size=(m, n))
    idx = np.array([0, 2, 5], dtype=np.int32)
    h = rng.normal(size=n)
    b = rng.normal(size=m)
    h2, f, rk = model.d_round_program(p, idx, h, b)
    want_h = ref.d_multi_sweep_ref(p, idx, h, b, 2)
    want_f = ref.fluid_ref(p, want_h, b, want_h[idx])
    np.testing.assert_allclose(np.asarray(h2), want_h, rtol=1e-12, atol=1e-12)
    np.testing.assert_allclose(np.asarray(f), want_f, rtol=1e-12, atol=1e-12)
    np.testing.assert_allclose(float(rk), np.sum(np.abs(want_f)), rtol=1e-12)


def test_jacobi_step_program():
    p, b = a1_system()
    h = np.array([0.1, 0.2, 0.3, 0.4])
    (got,) = model.jacobi_step_program(p, h, b)
    np.testing.assert_allclose(
        np.asarray(got), ref.jacobi_step_ref(p, h, b), rtol=1e-12
    )


def test_power_step_program_normalizes():
    rng = np.random.default_rng(2)
    n = 5
    p = rng.uniform(0, 1, size=(n, n))
    x = rng.uniform(0.1, 1, size=n)
    (got,) = model.power_step_program(p, x)
    np.testing.assert_allclose(np.asarray(got), ref.power_step_ref(p, x), rtol=1e-12)
    assert abs(np.sum(np.abs(np.asarray(got))) - 1.0) < 1e-12


def test_pagerank_step_program_mass_conservation():
    rng = np.random.default_rng(3)
    n = 8
    s = rng.uniform(0, 1, size=(n, n))
    s[:, :3] /= s[:, :3].sum(axis=0, keepdims=True)  # stochastic columns
    s[:, 3] = 0.0  # a dangling column
    s[:, 4:] /= s[:, 4:].sum(axis=0, keepdims=True)
    x = np.full(n, 1.0 / n)
    tp = np.full(n, 1.0 / n)
    (got,) = model.pagerank_step_program(s, x, tp, 0.85)
    want = ref.pagerank_step_ref(s, x, 0.85, tp)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-12)
    # PageRank step preserves total probability mass
    np.testing.assert_allclose(np.sum(np.asarray(got)), 1.0, rtol=1e-12)


def test_fluid_norm_program():
    p, b = a1_system()
    h = np.array([0.3, 0.1, 0.2, 0.5])
    (got,) = model.fluid_norm_program(p, h, b)
    np.testing.assert_allclose(float(got), ref.residual_norm_ref(p, h, b), rtol=1e-12)


def test_d_iteration_full_convergence_a1():
    """Golden shared with rust: X(A(1)) = [2/26,2/26,−1/16,6/16]·scale… —
    computed here by direct solve, checked against D-iteration trace."""
    a = np.array([[5.0, 3, 0, 0], [3, 7, 0, 0], [0, 0, 8, 4], [0, 0, 2, 3]])
    x = np.linalg.solve(a, np.ones(4))
    p, b = a1_system()
    seq = list(np.tile(np.arange(4), 60))
    h, trace = ref.d_iteration_ref(p, b, seq)
    np.testing.assert_allclose(h, x, rtol=1e-12, atol=1e-12)
    # error decreases monotonically on the cycle boundaries
    errs = [np.abs(t - x).sum() for t in trace[3::4]]
    assert all(e2 <= e1 + 1e-15 for e1, e2 in zip(errs, errs[1:]))


def test_programs_grid_shapes_consistent():
    """Every PROGRAMS grid entry must build a spec the function accepts."""
    import jax

    for name, (fn, spec_builder, grid) in model.PROGRAMS.items():
        for dims in grid[:1]:  # lowering all shapes is aot.py's job
            spec = spec_builder(*dims)
            jax.eval_shape(fn, *spec)
