"""Layer-1 correctness: every Pallas kernel vs the pure-numpy oracle.

This is the CORE correctness signal of the compile path. Shapes and dtypes
are swept both explicitly (the shapes we actually AOT) and via hypothesis.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import diffusion, matvec, ref

RNG = np.random.default_rng(0)


def random_contraction(m, n, rng, scale=0.9):
    """Rows with L1 norm < scale, so the D-iteration converges."""
    p = rng.uniform(-1.0, 1.0, size=(m, n))
    norms = np.abs(p).sum(axis=1, keepdims=True)
    norms[norms == 0] = 1.0
    return p / norms * scale * rng.uniform(0.1, 1.0, size=(m, 1))


# ---------------------------------------------------------------- d_sweep


@pytest.mark.parametrize("m,n", [(1, 1), (2, 4), (4, 4), (3, 7), (32, 128)])
def test_d_sweep_matches_ref(m, n):
    rng = np.random.default_rng(m * 1000 + n)
    p = random_contraction(m, n, rng)
    idx = rng.choice(n, size=m, replace=False).astype(np.int32)
    h = rng.normal(size=n)
    b = rng.normal(size=m)
    got = np.asarray(diffusion.d_sweep(p, idx, h, b))
    want = ref.d_sweep_ref(p, idx, h, b)
    np.testing.assert_allclose(got, want, rtol=1e-12, atol=1e-12)


def test_d_sweep_sequential_dependence():
    """Row t must see the H written by rows < t (the whole point)."""
    # P over 2 coords: update 0 from 1, then 1 from the *new* 0.
    p = np.array([[0.0, 0.5], [0.5, 0.0]])
    idx = np.array([0, 1], dtype=np.int32)
    h = np.array([0.0, 1.0])
    b = np.array([1.0, 1.0])
    got = np.asarray(diffusion.d_sweep(p, idx, h, b))
    # sequential: h0 = 0.5*1+1 = 1.5 ; h1 = 0.5*1.5+1 = 1.75
    np.testing.assert_allclose(got, [1.5, 1.75])
    # a Jacobi (parallel) update would give h1 = 0.5*0+1 = 1.0 — different.
    assert abs(got[1] - 1.0) > 0.5


def test_d_sweep_duplicate_indices():
    """The sequence I may revisit a coordinate within one block sweep."""
    rng = np.random.default_rng(7)
    p = random_contraction(4, 5, rng)
    idx = np.array([2, 2, 0, 2], dtype=np.int32)
    h = rng.normal(size=5)
    b = rng.normal(size=4)
    got = np.asarray(diffusion.d_sweep(p, idx, h, b))
    want = ref.d_sweep_ref(p, idx, h, b)
    np.testing.assert_allclose(got, want, rtol=1e-12, atol=1e-12)


def test_d_sweep_identity_rows_noop():
    """Zero rows with b = h[idx] leave H unchanged."""
    n = 6
    h = np.arange(n, dtype=np.float64)
    idx = np.array([1, 4], dtype=np.int32)
    p = np.zeros((2, n))
    b = h[idx]
    got = np.asarray(diffusion.d_sweep(p, idx, h, b))
    np.testing.assert_allclose(got, h)


def test_d_multi_sweep_converges_to_fixed_point():
    """Many sweeps over all coordinates must approach X = PX + B."""
    rng = np.random.default_rng(3)
    n = 8
    p = random_contraction(n, n, rng, scale=0.8)
    idx = np.arange(n, dtype=np.int32)
    b = rng.normal(size=n)
    x = np.linalg.solve(np.eye(n) - p, b)
    h = np.asarray(diffusion.d_multi_sweep(p, idx, b.copy(), b, 200))
    np.testing.assert_allclose(h, x, rtol=1e-10, atol=1e-10)


@settings(max_examples=40, deadline=None)
@given(
    m=st.integers(1, 8),
    n=st.integers(1, 12),
    seed=st.integers(0, 2**31 - 1),
)
def test_d_sweep_hypothesis(m, n, seed):
    m = min(m, n)
    rng = np.random.default_rng(seed)
    p = random_contraction(m, n, rng)
    idx = rng.choice(n, size=m, replace=False).astype(np.int32)
    h = rng.normal(size=n)
    b = rng.normal(size=m)
    got = np.asarray(diffusion.d_sweep(p, idx, h, b))
    want = ref.d_sweep_ref(p, idx, h, b)
    np.testing.assert_allclose(got, want, rtol=1e-11, atol=1e-11)


@pytest.mark.parametrize("dtype", [np.float32, np.float64])
def test_d_sweep_dtypes(dtype):
    rng = np.random.default_rng(11)
    p = random_contraction(3, 6, rng).astype(dtype)
    idx = np.array([0, 3, 5], dtype=np.int32)
    h = rng.normal(size=6).astype(dtype)
    b = rng.normal(size=3).astype(dtype)
    got = np.asarray(diffusion.d_sweep(p, idx, h, b))
    want = ref.d_sweep_ref(p, idx, h, b)
    tol = 1e-5 if dtype == np.float32 else 1e-12
    assert got.dtype == dtype
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol)


# ---------------------------------------------------------------- fluid / matvec


@pytest.mark.parametrize("m,n", [(1, 1), (2, 4), (4, 4), (16, 64), (128, 128)])
def test_fluid_matches_ref(m, n):
    rng = np.random.default_rng(m + 17 * n)
    p = rng.normal(size=(m, n))
    h = rng.normal(size=n)
    b = rng.normal(size=m)
    h_sel = rng.normal(size=m)
    got = np.asarray(matvec.fluid(p, h, b, h_sel))
    want = ref.fluid_ref(p, h, b, h_sel)
    np.testing.assert_allclose(got, want, rtol=1e-12, atol=1e-12)


@pytest.mark.parametrize("m,n", [(1, 3), (4, 4), (6, 10), (64, 64), (100, 32)])
def test_matvec_matches_ref(m, n):
    rng = np.random.default_rng(m * 31 + n)
    p = rng.normal(size=(m, n))
    x = rng.normal(size=n)
    got = np.asarray(matvec.matvec(p, x))
    np.testing.assert_allclose(got, ref.matvec_ref(p, x), rtol=1e-12, atol=1e-12)


@settings(max_examples=40, deadline=None)
@given(m=st.integers(1, 33), n=st.integers(1, 17), seed=st.integers(0, 2**31 - 1))
def test_matvec_hypothesis(m, n, seed):
    rng = np.random.default_rng(seed)
    p = rng.normal(size=(m, n))
    x = rng.normal(size=n)
    got = np.asarray(matvec.matvec(p, x))
    np.testing.assert_allclose(got, ref.matvec_ref(p, x), rtol=1e-11, atol=1e-11)


def test_residual_norm_zero_at_fixed_point():
    rng = np.random.default_rng(5)
    n = 10
    p = random_contraction(n, n, rng, scale=0.7)
    b = rng.normal(size=n)
    x = np.linalg.solve(np.eye(n) - p, b)
    r = float(matvec.residual_norm(p, x, b))
    assert r < 1e-10


def test_residual_norm_matches_ref():
    rng = np.random.default_rng(6)
    n = 12
    p = rng.normal(size=(n, n))
    h = rng.normal(size=n)
    b = rng.normal(size=n)
    got = float(matvec.residual_norm(p, h, b))
    want = ref.residual_norm_ref(p, h, b)
    np.testing.assert_allclose(got, want, rtol=1e-12)


def test_row_tile_divides():
    for m in [1, 2, 3, 4, 6, 8, 100, 128, 256, 129]:
        bm = matvec._row_tile(m)
        assert m % bm == 0
        assert 1 <= bm <= 128


# ---------------------------------------------------------------- paper worked example


def test_paper_a1_sweep():
    """The A(1) example of §5.1: cyclic D-iteration on P from A(1)."""
    a = np.array(
        [[5.0, 3, 0, 0], [3, 7, 0, 0], [0, 0, 8, 4], [0, 0, 2, 3]]
    )
    rhs = np.ones(4)
    p, b = ref.to_iteration_matrix(a, rhs)
    # paper's P (checked literally):
    np.testing.assert_allclose(
        p,
        [
            [0, -3 / 5, 0, 0],
            [-3 / 7, 0, 0, 0],
            [0, 0, 0, -4 / 8],
            [0, 0, -2 / 3, 0],
        ],
    )
    idx = np.arange(4, dtype=np.int32)
    h = np.asarray(diffusion.d_multi_sweep(p, idx, b.copy(), b, 100))
    x = np.linalg.solve(a, rhs)
    np.testing.assert_allclose(h, x, rtol=1e-12, atol=1e-12)
