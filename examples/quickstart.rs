//! Quickstart: solve the paper's A(1)·X = 1 example three ways —
//! sequentially, with 2 threaded PIDs (V1), and with 2 threaded PIDs (V2)
//! — and check all three against the exact LU solution.
//!
//! Run: `cargo run --release --example quickstart`

use diter::coordinator::{v1, v2, DistributedConfig};
use diter::graph::paper_matrix;
use diter::linalg::vec_ops::dist_inf;
use diter::partition::Partition;
use diter::solver::{DIteration, FixedPointProblem, SolveOptions, Solver};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    // the paper's A(1) (§5.1): two independent 2x2 blocks
    let a = paper_matrix(1);
    let problem = FixedPointProblem::from_linear_system(&a, &[1.0; 4])?;
    let exact = problem.exact_solution()?;
    println!("A(1)·X = (1,1,1,1)ᵗ, exact X = {exact:?}\n");

    // 1. sequential D-iteration (cyclic, H-form, free start H₀ = B)
    let sol = DIteration::cyclic().solve(&problem, &SolveOptions::default())?;
    println!(
        "sequential D-iteration : cost {:>5.1} passes, residual {:.2e}, Δ∞ {:.2e}",
        sol.cost,
        sol.residual,
        dist_inf(&sol.x, &exact)
    );

    // 2. V1 distributed (full H per PID, slice sharing)
    let cfg = DistributedConfig::new(Partition::contiguous(4, 2)?).with_tol(1e-12);
    let sol = v1::solve_v1(&problem, &cfg)?;
    println!(
        "V1, 2 PIDs             : cost {:>5.1} passes, residual {:.2e}, Δ∞ {:.2e}, {} msgs",
        sol.cost,
        sol.residual,
        dist_inf(&sol.x, &exact),
        sol.metrics["msgs_sent"]
    );

    // 3. V2 distributed (partial state, fluid parcels with ack+coalescing)
    let cfg = DistributedConfig::new(Partition::contiguous(4, 2)?).with_tol(1e-12);
    let sol = v2::solve_v2(&problem, &cfg)?;
    println!(
        "V2, 2 PIDs             : cost {:>5.1} passes, residual {:.2e}, Δ∞ {:.2e}, {} msgs",
        sol.cost,
        sol.residual,
        dist_inf(&sol.x, &exact),
        sol.metrics["msgs_sent"]
    );

    println!("\nall three agree with LU to ~1e-10 — see `diter figure --id 1` for the");
    println!("full error-vs-iteration chart of Figure 1.");
    Ok(())
}
