//! Streaming PageRank: the online-PageRank workload the streaming engine
//! opens up. A power-law web graph churns continuously (seeded random
//! rewires plus a hot-spot burst); after every mutation batch the engine
//! rebases the *running* distributed computation onto the new matrix
//! (§3.2: `F' = B' = P'·H + B − H`, per-PID) and reconverges warm — this
//! example measures that against a cold V2 restart on the same matrix.
//!
//! Run: `cargo run --release --example streaming_pagerank [nodes] [pids]`

use std::time::Duration;

use diter::bench_harness::{fmt_secs, Table};
use diter::coordinator::{v2, DistributedConfig, StreamingEngine};
use diter::graph::{power_law_web_graph, ChurnModel, MutableDigraph, MutationStream};
use diter::linalg::vec_ops::dist1;
use diter::partition::Partition;
use diter::solver::SequenceKind;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let args: Vec<String> = std::env::args().collect();
    let n: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(2_000);
    let k: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(4);
    let damping = 0.85;
    let tol = 1e-9;
    let batches = 6usize;
    let batch_size = 40usize;

    println!("== streaming PageRank: warm rebase vs cold restart ==");
    println!("N={n}, K={k} PIDs, tol {tol:.0e}, {batches} batches x {batch_size} mutations\n");

    let g = power_law_web_graph(n, 8, 0.1, 7);
    let mg = MutableDigraph::from_digraph(&g, n);
    let mut cfg = DistributedConfig::new(Partition::contiguous(n, k)?)
        .with_tol(tol)
        .with_seed(1)
        .with_sequence(SequenceKind::GreedyMaxFluid);
    cfg.max_wall = Duration::from_secs(120);
    let cold_cfg = cfg.clone();

    let mut engine = StreamingEngine::new(mg, damping, true, cfg)?;
    let init = engine.converge()?;
    if !init.solution.converged {
        return Err(format!("initial solve failed: {:.3e}", init.solution.residual).into());
    }
    println!(
        "initial solve: {} updates in {} (residual {:.2e})\n",
        init.solution.total_updates,
        fmt_secs(init.solution.wall_secs),
        init.solution.residual
    );

    let mut table = Table::new(&[
        "batch", "model", "applied", "warm-upd", "warm-wall", "cold-upd", "cold-wall", "speedup",
        "Δ₁(warm,cold)",
    ]);
    let mut rewire = MutationStream::new(ChurnModel::RandomRewire, 23);
    let mut hotspot = MutationStream::new(ChurnModel::HotSpotBurst { burst: 24 }, 29);
    let mut warm_updates_total = 0u64;
    let mut cold_updates_total = 0u64;

    for b in 0..batches {
        // alternate churn models: steady rewires with a hot-spot burst mixed in
        let (model_name, batch) = if b % 3 == 2 {
            ("hotspot", hotspot.next_batch(engine.graph(), batch_size))
        } else {
            ("rewire", rewire.next_batch(engine.graph(), batch_size))
        };
        let report = engine.apply_batch(&batch)?;
        if !report.solution.converged {
            return Err(format!(
                "batch {b}: failed to reconverge (residual {:.3e})",
                report.solution.residual
            )
            .into());
        }
        // the cold baseline: a full V2 restart on the same (new) matrix
        let cold = v2::solve_v2(engine.problem(), &cold_cfg)?;
        if !cold.converged {
            return Err(format!("batch {b}: cold restart failed").into());
        }
        let delta = dist1(&report.solution.x, &cold.x);
        if !(delta.is_finite() && delta <= 1e-6) {
            return Err(format!("batch {b}: warm and cold disagree: Δ₁ = {delta:.3e}").into());
        }
        warm_updates_total += report.solution.total_updates;
        cold_updates_total += cold.total_updates;
        let speedup = cold.total_updates as f64 / report.solution.total_updates.max(1) as f64;
        table.row(&[
            b.to_string(),
            model_name.to_string(),
            report.mutations_applied.to_string(),
            report.solution.total_updates.to_string(),
            fmt_secs(report.solution.wall_secs),
            cold.total_updates.to_string(),
            fmt_secs(cold.wall_secs),
            format!("{speedup:.1}x"),
            format!("{delta:.1e}"),
        ]);
    }
    print!("{}", table.render());

    let overall = cold_updates_total as f64 / warm_updates_total.max(1) as f64;
    let summary = engine.finish()?;
    println!(
        "\ntotals: warm {warm_updates_total} vs cold {cold_updates_total} scalar updates \
         ({overall:.1}x less work staying warm)"
    );
    println!(
        "{} epochs, {} mutations applied, steady-state {:.2e} upd/s, final residual {:.2e}",
        summary.epochs,
        summary.mutations_applied,
        summary.steady_updates_per_sec,
        summary.final_solution.residual
    );
    if !(overall.is_finite() && overall > 1.0) {
        return Err(format!(
            "warm rebase should beat a cold restart on small mutation batches \
             (got {overall:.2}x)"
        )
        .into());
    }
    println!("\nOK — the engine reconverges measurably faster than restarting.");
    Ok(())
}
