//! Elastic worker pool demo: a straggling PID triggers a live worker
//! spawn mid-convergence; the spawned worker absorbs half the
//! straggler's Ω over the ownership-handoff machinery and the solve
//! lands on the exact PageRank fixed point — then a flash-crowd burst
//! reconverges across the grown pool.
//!
//! Run: `cargo build --release --examples && ./target/release/examples/elastic_hotspot`

use std::time::Duration;

use diter::coordinator::{DistributedConfig, ElasticConfig, StreamingEngine};
use diter::graph::{power_law_web_graph, ChurnModel, MutableDigraph, MutationStream};
use diter::linalg::vec_ops::norm1;
use diter::partition::Partition;
use diter::solver::SequenceKind;

fn main() {
    let n = 600;
    let k = 2;
    println!("elastic pool: {n}-page web graph, K0 = {k}, PID 0 throttled to 12k upd/s");
    let g = power_law_web_graph(n, 6, 0.1, 7);
    let mg = MutableDigraph::from_digraph(&g, n);
    let mut cfg = DistributedConfig::new(Partition::contiguous(n, k).unwrap())
        .with_tol(1e-9)
        .with_seed(7)
        .with_sequence(SequenceKind::GreedyMaxFluid)
        .with_straggler(0, 12_000.0)
        .with_elastic(ElasticConfig {
            max_workers: 4,
            spawn_threshold: 0.5,
            retire_idle: Duration::from_secs(10),
            interval: Duration::from_millis(10),
            ..Default::default()
        });
    cfg.max_wall = Duration::from_secs(120);
    let mut eng = StreamingEngine::new(mg, 0.85, true, cfg).expect("engine");

    let init = eng.converge().expect("initial solve");
    let stats = eng.pool_stats();
    println!(
        "initial solve: converged={} residual={:.2e} wall={:.3}s — pool spawned {} (peak {} workers)",
        init.solution.converged,
        init.solution.residual,
        init.solution.wall_secs,
        stats.spawned,
        stats.peak_live
    );
    assert!(init.solution.converged, "must converge");
    assert!(
        stats.spawned >= 1,
        "the straggler must have triggered a live spawn"
    );
    let mass = norm1(&init.solution.x);
    assert!(
        (mass - 1.0).abs() < 1e-6,
        "fluid conserved through the spawn: ‖x‖₁ = {mass}"
    );

    // flash crowd: a burst of links at one suddenly-popular page
    let mut stream = MutationStream::new(ChurnModel::HotSpotBurst { burst: 32 }, 0xF1A5);
    let batch = stream.next_batch(eng.graph(), 32);
    let report = eng.apply_batch(&batch).expect("hotspot epoch");
    println!(
        "hotspot epoch: converged={} residual={:.2e} wall={:.3}s across {} live workers",
        report.solution.converged,
        report.solution.residual,
        report.solution.wall_secs,
        eng.pool_stats().live
    );
    assert!(report.solution.converged, "hotspot epoch must reconverge");
    let mass = norm1(&report.solution.x);
    assert!((mass - 1.0).abs() < 1e-6, "‖x‖₁ = {mass}");

    let ownership = eng.ownership();
    println!("final ownership: |Ω_k| = {:?}", ownership.part_sizes());
    let stats = eng.pool_stats();
    println!(
        "pool lifecycle: spawned {} retired {} sheds {} peak {} live {}",
        stats.spawned, stats.retired, stats.sheds, stats.peak_live, stats.live
    );
    eng.finish().expect("shutdown");
    println!("OK — live split absorbed the straggler; fixed point intact");
}
