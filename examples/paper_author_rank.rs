//! Joint publications + authors ranking (paper ref [5]: Hong & Baccelli):
//! a PageRank-style fixed point on a bipartite-ish citation/authorship
//! graph, solved with the V2 distributed D-iteration.
//!
//! Papers cite older papers; papers point to their authors and authors to
//! their papers, so reputation flows both ways — a paper is good if cited
//! by good papers and written by good authors, and vice versa.
//!
//! Run: `cargo run --release --example paper_author_rank`

use std::time::Duration;

use diter::coordinator::{v2, DistributedConfig};
use diter::graph::{pagerank_system, paper_author_graph};
use diter::linalg::vec_ops::norm1;
use diter::partition::Partition;
use diter::solver::{FixedPointProblem, SequenceKind};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let n_papers = 3_000;
    let n_authors = 400;
    println!("== joint paper/author ranking ({n_papers} papers, {n_authors} authors) ==");
    let pa = paper_author_graph(n_papers, n_authors, 4, 2, 77);
    let n = pa.graph.n();
    println!("graph: {} nodes, {} edges", n, pa.graph.m());

    let sys = pagerank_system(&pa.graph, 0.85, false)?;
    let problem = FixedPointProblem::new(sys.matrix.clone(), sys.b.clone())?;

    // partition along the node classes: papers split among K−1 PIDs, the
    // authors (hub nodes) get their own PID — a natural locality split
    let k = 4;
    let mut owner = vec![0usize; n];
    for (i, o) in owner.iter_mut().enumerate() {
        *o = if i >= n_papers {
            k - 1 // authors
        } else {
            i * (k - 1) / n_papers
        };
    }
    let partition = Partition::from_owner(owner, k)?;
    println!(
        "partition: {k} PIDs (authors isolated on PID {}), cut {:.3}",
        k - 1,
        partition.cut_fraction(problem.matrix().csr())
    );

    let mut cfg = DistributedConfig::new(partition)
        .with_tol(1e-10)
        .with_sequence(SequenceKind::GreedyMaxFluid)
        .with_seed(3);
    cfg.max_wall = Duration::from_secs(120);
    let sol = v2::solve_v2(&problem, &cfg)?;
    if !sol.converged {
        return Err(format!("did not converge: {}", sol.residual).into());
    }
    println!(
        "solved: wall {:.3}s, {:.2e} upd/s, {} msgs, ‖x‖₁ = {:.9}",
        sol.wall_secs,
        sol.updates_per_sec(),
        sol.metrics["msgs_sent"],
        norm1(&sol.x)
    );

    let mut papers: Vec<(usize, f64)> = (0..n_papers).map(|i| (i, sol.x[i])).collect();
    let mut authors: Vec<(usize, f64)> =
        (n_papers..n).map(|i| (i - n_papers, sol.x[i])).collect();
    papers.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
    authors.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());

    println!("\ntop 5 papers:");
    for (rank, (p, s)) in papers.iter().take(5).enumerate() {
        println!("  #{} paper {:>6}  score {:.5e}", rank + 1, p, s);
    }
    println!("top 5 authors:");
    for (rank, (a, s)) in authors.iter().take(5).enumerate() {
        println!("  #{} author {:>5}  score {:.5e}", rank + 1, a, s);
    }
    // sanity: early (much-cited) papers should outrank the newest ones
    let early: f64 = (0..50).map(|i| sol.x[i]).sum();
    let late: f64 = (n_papers - 50..n_papers).map(|i| sol.x[i]).sum();
    if !(early.is_finite() && late.is_finite() && early > late) {
        return Err(
            format!("citation flow should favor early papers ({early:.3e} vs {late:.3e})").into(),
        );
    }
    println!(
        "\nOK — early papers outrank late ones ({:.2}x), as citation flow dictates.",
        early / late
    );
    Ok(())
}
