//! Live matrix evolution (§3.2): a web graph keeps changing while PageRank
//! is being computed. After each batch of edge mutations the running
//! computation rebases (`B' = F + (P'−P)·H`) and continues warm — this
//! example measures how much cheaper that is than restarting cold.
//!
//! Run: `cargo run --release --example dynamic_matrix`

use diter::coordinator::update;
use diter::graph::{pagerank_system, power_law_web_graph, Digraph};
use diter::linalg::vec_ops::dist1;
use diter::prng::Xoshiro256pp;
use diter::solver::{DIteration, FixedPointProblem, SolveOptions, Solver};

fn mutate(g: &Digraph, rng: &mut Xoshiro256pp, edits: usize) -> Digraph {
    // re-generate the edge list with `edits` random additions
    let n = g.n();
    let mut edges: Vec<(usize, usize)> = Vec::with_capacity(g.m() + edits);
    for u in 0..n {
        for &v in g.out_neighbors(u) {
            edges.push((u, v));
        }
    }
    for _ in 0..edits {
        edges.push((rng.below(n), rng.below(n)));
    }
    Digraph::from_edges(n, edges)
}

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let n = 5_000;
    let damping = 0.85;
    let tight = SolveOptions {
        tol: 1e-10,
        max_cost: 100_000.0,
        trace_every: 0.0,
        exact: None,
    };
    let mut rng = Xoshiro256pp::seed_from_u64(23);

    println!("== §3.2 live matrix evolution: warm rebase vs cold restart ==");
    println!("web graph N={n}, 5 mutation batches of growing size\n");
    let mut g = power_law_web_graph(n, 8, 0.1, 11);
    let sys = pagerank_system(&g, damping, true)?;
    let mut problem = FixedPointProblem::new(sys.matrix.clone(), sys.b.clone())?;
    let mut h = DIteration::greedy().solve(&problem, &tight)?.x;
    println!("initial solve: done (residual {:.1e})", problem.residual_norm(&h));
    println!(
        "\n{:>8} {:>12} {:>12} {:>9} {:>12}",
        "edits", "warm-cost", "cold-cost", "saving", "drift‖Δx‖₁"
    );

    for edits in [10usize, 50, 200, 1000, 5000] {
        g = mutate(&g, &mut rng, edits);
        let sys = pagerank_system(&g, damping, true)?;
        let new_problem = FixedPointProblem::new(sys.matrix.clone(), sys.b.clone())?;

        // warm: rebase B' = P'H + B − H, solve the correction, add back
        let b_prime = update::rebase_b(new_problem.matrix(), &h, new_problem.b())?;
        let sub = FixedPointProblem::new(new_problem.matrix().clone(), b_prime)?;
        let warm = DIteration::greedy().solve(&sub, &tight)?;
        let warm_x: Vec<f64> = h.iter().zip(&warm.x).map(|(a, b)| a + b).collect();

        // cold: from scratch
        let cold = DIteration::greedy().solve(&new_problem, &tight)?;

        let drift = dist1(&warm_x, &h);
        println!(
            "{:>8} {:>12.1} {:>12.1} {:>8.1}x {:>12.3e}",
            edits,
            warm.cost,
            cold.cost,
            cold.cost / warm.cost.max(1e-9),
            drift
        );
        // verify both routes agree
        let delta = dist1(&warm_x, &cold.x);
        if !(delta.is_finite() && delta < 1e-6) {
            return Err(format!("warm and cold disagree: {delta}").into());
        }
        problem = new_problem;
        h = warm_x;
    }
    let _ = &problem;
    println!("\nwarm rebase converges to the same limit at a fraction of the cost");
    println!("for small edits — exactly the §3.2 claim (Theorem 4 of [4]).");
    Ok(())
}
