//! END-TO-END DRIVER (DESIGN.md §6): distributed PageRank on a synthetic
//! power-law web graph — the workload the paper's §5/§6 motivates.
//!
//! Exercises the full stack on a real small workload:
//!   graph generator → PageRank fixed-point system → partitioner →
//!   V2 distributed D-iteration over the async bus (ack + coalescing) →
//!   §4.4 distance-to-limit certificate → verification against a
//!   sequential power-method reference.
//!
//! Run: `cargo run --release --example pagerank_websim [nodes] [pids]`
//! Results recorded in EXPERIMENTS.md §End-to-end.

use std::time::Duration;

use diter::coordinator::{v2, DistributedConfig};
use diter::graph::{pagerank_reference, pagerank_system, power_law_web_graph};
use diter::linalg::vec_ops::{dist1, norm1};
use diter::metrics::Stopwatch;
use diter::partition::Partition;
use diter::solver::{ConvergenceBound, FixedPointProblem, SequenceKind};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let args: Vec<String> = std::env::args().collect();
    let n: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(50_000);
    let k: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(4);
    let damping = 0.85;
    let tol = 1e-9;

    println!("== diter end-to-end: distributed PageRank ==");
    let sw = Stopwatch::start();
    let g = power_law_web_graph(n, 8, 0.1, 7);
    println!(
        "graph      : {} nodes, {} edges, {} dangling ({} ms to generate)",
        g.n(),
        g.m(),
        g.dangling_nodes().len(),
        sw.elapsed_ms() as u64
    );
    // dangling handling: the UNPATCHED ("strongly preferential") convention —
    // patching would materialize one dense column per dangling page
    // (≈ dangling×N extra nnz); the paper notes the §4.4 expression is then
    // an upper bound. Rankings follow the standard renormalize-at-the-end.
    let sys = pagerank_system(&g, damping, false)?;
    let problem = FixedPointProblem::new(sys.matrix.clone(), sys.b.clone())?;
    let bound = ConvergenceBound::for_matrix(problem.matrix(), Some(damping));
    println!(
        "system     : nnz {}, max col norm {:.4} (§4.4 bound: r/(1-d))",
        problem.matrix().nnz(),
        problem.matrix().max_col_norm()
    );

    let partition = Partition::contiguous(n, k)?;
    println!(
        "partition  : K={k} contiguous, cut fraction {:.3}",
        partition.cut_fraction(problem.matrix().csr())
    );

    let mut cfg = DistributedConfig::new(partition)
        .with_tol(tol)
        .with_seed(1)
        .with_sequence(SequenceKind::GreedyMaxFluid);
    cfg.max_wall = Duration::from_secs(300);
    let sol = v2::solve_v2(&problem, &cfg)?;
    println!("\n-- V2 distributed run --");
    println!("converged  : {}", sol.converged);
    println!("wall       : {:.3} s", sol.wall_secs);
    println!("updates    : {} total ({:.2e}/s)", sol.total_updates, sol.updates_per_sec());
    println!("parallel   : {:.1} equivalent passes", sol.cost);
    println!(
        "transport  : {} msgs, {:.2} MB, peak in-flight fluid {:.2e}",
        sol.metrics["msgs_sent"],
        sol.metrics["bytes_sent"] as f64 / 1e6,
        sol.metrics["inflight_peak_ppm"] as f64 / 1e6
    );
    println!(
        "certificate: residual {:.3e} → ‖X−H‖₁ ≤ {:.3e} (§4.4)",
        sol.residual,
        bound.distance(sol.residual)
    );
    println!("mass       : ‖x‖₁ = {:.6} (<1: unpatched dangling loss)", norm1(&sol.x));

    // verification against the sequential reference
    let sw = Stopwatch::start();
    let reference = pagerank_reference(&sys, 1e-12, 10_000);
    let seq_wall = sw.elapsed_secs();
    let delta = dist1(&sol.x, &reference);
    println!("\n-- verification --");
    println!("sequential power-style reference: {seq_wall:.3} s");
    println!("‖x_distributed − x_reference‖₁ = {delta:.3e}");
    if !(delta.is_finite() && delta < 1e-6) {
        return Err(format!("distributed result disagrees with reference: {delta}").into());
    }

    let mut ranked: Vec<(usize, f64)> = sol.x.iter().copied().enumerate().collect();
    ranked.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
    println!("\ntop 5 pages:");
    for (rank, (page, score)) in ranked.iter().take(5).enumerate() {
        println!("  #{} page {:>7}  score {:.6e}", rank + 1, page, score);
    }
    println!("\nOK — full stack verified end-to-end.");
    Ok(())
}
