//! Multi-tenant personalized PageRank: several seeded queries share one
//! worker pool, each diffusing in its own fluid lane while graph churn
//! runs underneath (DESIGN.md §10).
//!
//! Run: `cargo run --release --example serve_ppr`

use std::time::Duration;

use diter::coordinator::{
    DistributedConfig, Query, QueryState, ServeConfig, ServeEngine,
};
use diter::graph::{power_law_web_graph, ChurnModel, MutableDigraph, MutationStream};
use diter::linalg::vec_ops::{dist1, norm1};
use diter::partition::Partition;
use diter::solver::{DIteration, FixedPointProblem, SolveOptions, Solver};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let n = 600;
    let damping = 0.85;
    let g = power_law_web_graph(n, 6, 0.1, 11);
    let mg = MutableDigraph::from_digraph(&g, n);
    let cfg = DistributedConfig::new(Partition::contiguous(n, 3)?)
        .with_tol(1e-9)
        .with_seed(11);
    // 2 concurrent query lanes on top of the base PageRank lane
    let mut serve = ServeEngine::new(mg, damping, true, cfg, ServeConfig::default(), 2)?;

    // four queries for two lanes: the third and fourth wait in the
    // admission queue until a lane frees up
    let seed_sets: [&[usize]; 4] = [&[3, 17], &[42], &[100, 101, 102], &[7]];
    let mut pending = Vec::new();
    for seeds in seed_sets {
        let qid = serve
            .submit(Query::ppr(seeds, damping, 1e-8))
            .expect("queue has room for all four");
        pending.push((qid, seeds));
        println!("submitted query {qid} teleporting to {seeds:?}");
    }

    // serve them all, churning the graph midway through
    let mut churned = false;
    let mut served = Vec::new();
    let deadline = std::time::Instant::now() + Duration::from_secs(60);
    while served.len() < seed_sets.len() && std::time::Instant::now() < deadline {
        for done in serve.poll()? {
            assert_eq!(done.state, QueryState::Served, "no deadlines configured");
            println!(
                "query {} served from lane {} in {:.1} ms",
                done.qid,
                done.lane,
                done.time_to_eps_secs.unwrap_or(0.0) * 1e3
            );
            served.push(done);
            if !churned {
                // admission keeps flowing across the epoch boundary
                churned = true;
                let mut stream = MutationStream::new(ChurnModel::RandomRewire, 5);
                let batch = stream.next_batch(serve.engine().graph(), 20);
                let applied = serve.apply_mutations(&batch)?;
                println!("churned the graph mid-serve: {applied} mutations applied");
            }
        }
        std::thread::sleep(Duration::from_micros(200));
    }
    assert_eq!(served.len(), seed_sets.len(), "every query must complete");

    // every tenant's readout is a unit-mass PPR vector, and each matches
    // an independent single-query solve of the same (post-churn) system
    let problem = serve.engine().problem();
    for done in &served {
        let x = done.x.as_ref().expect("served queries carry a readout");
        let mass = norm1(x);
        let seeds = pending.iter().find(|(q, _)| *q == done.qid).unwrap().1;
        // Δ₁ is informational: queries served before the churn epoch
        // converged against the pre-churn matrix, so only the post-churn
        // ones land within ε of this (current-matrix) reference
        let q = Query::ppr(seeds, damping, 1e-8);
        let mut b = vec![0.0; n];
        for (c, m) in &q.seeds {
            b[*c] += m;
        }
        let single = FixedPointProblem::new(problem.matrix().clone(), b)?;
        let opts = SolveOptions {
            tol: 1e-12,
            max_cost: 200_000.0,
            trace_every: 0.0,
            exact: None,
        };
        let want = DIteration::fluid_cyclic().solve(&single, &opts)?.x;
        println!(
            "query {}: ‖x‖₁ = {mass:.6}, Δ₁ vs independent solve = {:.2e}",
            done.qid,
            dist1(x, &want)
        );
        assert!((mass - 1.0).abs() < 1e-3, "unit PPR mass, got {mass}");
    }

    let (admitted, served_n, rejected) = serve.counts();
    println!("\nadmitted {admitted}, served {served_n}, rejected {rejected}");
    serve.finish()?;
    println!("multi-tenant serving done — N queries, one matrix walk.");
    Ok(())
}
